"""RG-LRU diagonal linear recurrence as a Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t over time, per channel.  Grid
(batch, channel_blocks, time_chunks) with the time axis innermost
(sequential); the running h lives in VMEM scratch.  Within a chunk the
recurrence is unrolled with a fori_loop of VPU element-wise ops — the
"sequential grid" TPU variant of the GPU parallel-scan kernels; the
associative-scan alternative is what models/rglru.py uses at the XLA
level (DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128
DEFAULT_BLOCK_D = 256


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, h_out_ref, h_ref, *,
                  chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0]

    a = a_ref[0]                                   # [C, D]
    b = b_ref[0]

    def body(t, h):
        h = a[t] * h + b[t]
        y_ref[0, t] = h
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == nc - 1)
    def _emit():
        h_out_ref[0] = h


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def rglru_pallas(a, b, h0, *, chunk: int = DEFAULT_CHUNK,
                 block_d: int = DEFAULT_BLOCK_D, interpret: bool = False):
    """a, b [B,T,D] f32; h0 [B,D] f32 -> (h [B,T,D], h_T [B,D])."""
    B, T, D = a.shape
    pad_t = (-T) % chunk
    if pad_t:
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, 0)))
    block_d = min(block_d, D)
    pad_d = (-D) % block_d
    if pad_d:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_d)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad_d)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_d)))
    Tp, Dp = T + pad_t, D + pad_d

    grid = (B, Dp // block_d, Tp // chunk)
    y, hT = pl.pallas_call(
        functools.partial(_rglru_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b_, d, c: (b_, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b_, d, c: (b_, c, d)),
            pl.BlockSpec((1, block_d), lambda b_, d, c: (b_, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b_, d, c: (b_, c, d)),
            pl.BlockSpec((1, block_d), lambda b_, d, c: (b_, d)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tp, Dp), jnp.float32),
            jax.ShapeDtypeStruct((B, Dp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return y[:, :T, :D], hT[:, :D]
