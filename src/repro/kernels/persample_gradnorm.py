"""Fused per-sample gradient-variance kernel (FedCGD Eq. 10 hot-spot).

For a softmax-CE head W in R^{d x C}, the per-sample gradient is the rank-1
matrix g_i = h_i (p_i - y_i)^T, so

    ||g_i||^2         = ||h_i||^2 * ||e_i||^2
    mean_i g_i        = H^T E / B          (one [d, C] matmul)
    sigma^2           = mean ||g_i||^2 - ||gbar||^2

The kernel fuses softmax, the one-hot subtraction and both row-norms per
batch block in VMEM, accumulating the [d, C] gbar partial in scratch —
never materializing the [B, d, C] per-sample gradient tensor that a naive
vmap(grad) implementation would (a B x d x C = 32 x 120 x 10 write per
device per round on every FL client).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_B = 128


def _psg_kernel(h_ref, logits_ref, labels_ref, gisq_ref, hte_ref, acc_ref, *,
                block_b: int, total_b: int):
    bi = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(bi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[...].astype(jnp.float32)             # [Bb, d]
    logits = logits_ref[...].astype(jnp.float32)   # [Bb, C]
    labels = labels_ref[...]                       # [Bb]
    C = logits.shape[-1]

    # batch-padding mask
    row = bi * block_b + jax.lax.broadcasted_iota(
        jnp.int32, (block_b,), 0)
    valid = (row < total_b).astype(jnp.float32)

    m = logits.max(axis=-1, keepdims=True)
    z = jnp.exp(logits - m)
    p = z / z.sum(axis=-1, keepdims=True)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (block_b, C), 1)
              == labels[:, None]).astype(jnp.float32)
    e = (p - onehot) * valid[:, None]

    gisq_ref[...] = (h * h).sum(-1) * (e * e).sum(-1)
    acc_ref[...] += jax.lax.dot_general(
        h, e, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # [d, C]

    @pl.when(bi == nb - 1)
    def _emit():
        hte_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def persample_gradnorm_pallas(features, logits, labels, *,
                              block_b: int = DEFAULT_BLOCK_B,
                              interpret: bool = False):
    """features [B,d], logits [B,C], labels [B] ->
    (sigma scalar, gi_sq [B])."""
    B, d = features.shape
    C = logits.shape[-1]
    block_b = min(block_b, B)
    pad = (-B) % block_b
    if pad:
        features = jnp.pad(features, ((0, pad), (0, 0)))
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, pad),))
    Bp = B + pad

    grid = (Bp // block_b,)
    gi_sq, hte = pl.pallas_call(
        functools.partial(_psg_kernel, block_b=block_b, total_b=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, C), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((d, C), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
            jax.ShapeDtypeStruct((d, C), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, C), jnp.float32)],
        interpret=interpret,
    )(features, logits, labels.astype(jnp.int32))
    gi_sq = gi_sq[:B]
    gbar = hte / B
    sigma_sq = gi_sq.mean() - jnp.sum(gbar * gbar)
    return jnp.sqrt(jnp.maximum(sigma_sq, 0.0)), gi_sq
