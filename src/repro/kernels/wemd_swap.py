"""Batched WEMD swap/add candidate kernels (FSCD / GS hot loops).

The FSCD inner loop (Algorithm 2) evaluates, per problem, the dense
swap-candidate matrix

    W[i, j] = sum_c cw_c * | (p_sum - p_dev_i + p_dev_j) / S  -  gd_c |

over every (i in set, j out of set) pair, and GS (Algorithm 1) its rank-1
analogue W[v] over add candidates.  Batched across a [B] problem axis
these are the scheduler's compute hot-spots (O(B V^2 C) per FSCD step).

``wemd_swap_pallas`` tiles the i-rows and the class axis: each grid step
loads one [block_i, block_c] slab of member rows plus the full [V,
block_c] candidate slab, forms the |.| term in VMEM and accumulates the
class-partial sums into the [block_i, V] output block — the [V, V, C]
intermediate never exists in HBM.  ``wemd_add_pallas`` does the same for
the [B, V] add matrix.

Both kernels are float32 (TPU-native); the float64 parity path used for
mask-exact scheduling on CPU lives in ``core/scheduling_jax.py``.
Validity masking (membership, bandwidth) is the caller's job — the
kernels compute the dense matrices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_I = 8
DEFAULT_BLOCK_C = 128


def _swap_kernel(psum_ref, pdi_ref, pdj_ref, gd_ref, cw_ref, sz_ref,
                 out_ref):
    ct = pl.program_id(2)

    @pl.when(ct == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = sz_ref[0, 0]
    ps = psum_ref[0]                               # [bc]
    gd = gd_ref[0]
    cw = cw_ref[0]
    pi = pdi_ref[0]                                # [bi, bc]
    pj = pdj_ref[0]                                # [V,  bc]
    base = (ps[None, None, :] - pi[:, None, :]) + pj[None, :, :]
    out_ref[0] += jnp.sum(jnp.abs(base / s - gd[None, None, :])
                          * cw[None, None, :], axis=-1)


def _add_kernel(psum_ref, pd_ref, gd_ref, cw_ref, sz_ref, out_ref):
    ct = pl.program_id(1)

    @pl.when(ct == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = sz_ref[0, 0]
    new = (psum_ref[0][None, :] + pd_ref[0]) / (s + 1.0)   # [V, bc]
    out_ref[0] += jnp.sum(jnp.abs(new - gd_ref[0][None, :])
                          * cw_ref[0][None, :], axis=-1)


def _pad_class(x, pad_c):
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad_c)])


@functools.partial(jax.jit, static_argnames=("block_i", "block_c",
                                             "interpret"))
def wemd_swap_pallas(p_sum, p_dev, global_dist, class_weights, sizes, *,
                     block_i: int = DEFAULT_BLOCK_I,
                     block_c: int = DEFAULT_BLOCK_C,
                     interpret: bool = False):
    """p_sum [B,C], p_dev [B,V,C], global_dist/class_weights [B,C],
    sizes [B] (set sizes, >= 1) -> dense swap matrix [B, V, V]."""
    B, V, C = p_dev.shape
    block_c = min(block_c, C)
    pad_c = (-C) % block_c
    pad_v = (-V) % block_i
    f32 = jnp.float32
    # padded classes get zero weight -> contribute nothing to the sum
    p_sum = _pad_class(p_sum.astype(f32), pad_c)
    gd = _pad_class(global_dist.astype(f32), pad_c)
    cw = _pad_class(class_weights.astype(f32), pad_c)
    pd = jnp.pad(p_dev.astype(f32), ((0, 0), (0, pad_v), (0, pad_c)))
    Vp, Cp = V + pad_v, C + pad_c
    sz = jnp.reshape(sizes.astype(f32), (B, 1))

    grid = (B, Vp // block_i, Cp // block_c)
    out = pl.pallas_call(
        _swap_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c), lambda b, it, ct: (b, ct)),
            pl.BlockSpec((1, block_i, block_c),
                         lambda b, it, ct: (b, it, ct)),
            pl.BlockSpec((1, Vp, block_c), lambda b, it, ct: (b, 0, ct)),
            pl.BlockSpec((1, block_c), lambda b, it, ct: (b, ct)),
            pl.BlockSpec((1, block_c), lambda b, it, ct: (b, ct)),
            pl.BlockSpec((1, 1), lambda b, it, ct: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_i, Vp),
                               lambda b, it, ct: (b, it, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Vp, Vp), f32),
        interpret=interpret,
    )(p_sum, pd, pd, gd, cw, sz)
    return out[:, :V, :V]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def wemd_add_pallas(p_sum, p_dev, global_dist, class_weights, sizes, *,
                    block_c: int = DEFAULT_BLOCK_C,
                    interpret: bool = False):
    """p_sum [B,C], p_dev [B,V,C], global_dist/class_weights [B,C],
    sizes [B] (current set sizes, >= 0) -> add matrix [B, V]."""
    B, V, C = p_dev.shape
    block_c = min(block_c, C)
    pad_c = (-C) % block_c
    f32 = jnp.float32
    p_sum = _pad_class(p_sum.astype(f32), pad_c)
    gd = _pad_class(global_dist.astype(f32), pad_c)
    cw = _pad_class(class_weights.astype(f32), pad_c)
    pd = jnp.pad(p_dev.astype(f32), ((0, 0), (0, 0), (0, pad_c)))
    Cp = C + pad_c
    sz = jnp.reshape(sizes.astype(f32), (B, 1))

    grid = (B, Cp // block_c)
    out = pl.pallas_call(
        _add_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c), lambda b, ct: (b, ct)),
            pl.BlockSpec((1, V, block_c), lambda b, ct: (b, 0, ct)),
            pl.BlockSpec((1, block_c), lambda b, ct: (b, ct)),
            pl.BlockSpec((1, block_c), lambda b, ct: (b, ct)),
            pl.BlockSpec((1, 1), lambda b, ct: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, V), lambda b, ct: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, V), f32),
        interpret=interpret,
    )(p_sum, pd, gd, cw, sz)
    return out
