"""RWKV6 WKV recurrence as a chunked Pallas TPU kernel.

Grid (batch*heads, num_chunks): the chunk axis is the innermost
(sequential) grid dim, so the [hd, hd] recurrent state lives in VMEM
scratch and is carried across chunks.  Within a chunk all pairwise decay
products are computed in log space (every exponent <= 0, no overflow) and
contracted on the MXU; this is the TPU-native adaptation of the GPU
token-parallel WKV kernels (DESIGN.md §3).

VMEM per step: 4 chunk blocks [C, hd] + pair tensor [C, C, hd] + state
[hd, hd] f32 — with C=16, hd=64: ~350 kB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 16


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, s_ref,
                *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)              # [C, hd]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)              # [hd]

    logw = jnp.log(jnp.clip(w, 1e-8, 1.0))        # [C, hd], <= 0
    lc = jnp.cumsum(logw, axis=0)
    lc_prev = lc - logw                           # log prod_{u<=t-1}
    lct = lc[-1]                                  # [hd]

    S = s_ref[...]
    # inter-chunk
    rdec = r * jnp.exp(lc_prev)
    y = jax.lax.dot_general(rdec, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk pairwise (log-space, strictly lower triangular)
    ldiff = lc_prev[:, None, :] - lc[None, :, :]  # [C, C, hd]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (s_idx < t_idx)[:, :, None]
    pair = jnp.where(tri, ldiff, -1e30)
    A = (r[:, None, :] * jnp.exp(pair) * k[None, :, :]).sum(axis=-1)
    A = A + jnp.where(
        (s_idx == t_idx), ((r * u[None, :] * k).sum(axis=-1))[:, None], 0.0)
    y = y + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    kdec = k * jnp.exp(lct[None, :] - lc)
    s_ref[...] = jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + jnp.exp(lct)[:, None] * S

    @pl.when(ci == nc - 1)
    def _emit_state():
        s_out_ref[0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas(r, k, v, w, u, *, chunk: int = DEFAULT_CHUNK,
               interpret: bool = False):
    """r,k,v,w [B,T,H,hd]; u [H,hd] -> (y [B,T,H,hd], state [B,H,hd,hd])."""
    B, T, H, hd = r.shape
    pad = (-T) % chunk
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    Tp = T + pad

    def flat(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, Tp, hd)

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w)
    uf = jnp.broadcast_to(u, (B, H, hd)).reshape(B * H, hd)

    grid = (B * H, Tp // chunk)
    y, s = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Tp, hd), r.dtype),
            jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf)
    y = y.reshape(B, H, Tp, hd).transpose(0, 2, 1, 3)[:, :T]
    return y, s.reshape(B, H, hd, hd)
