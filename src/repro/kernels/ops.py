"""Jit'd public wrappers over the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes as Python/jnp, validating the exact TPU code path.
On a real TPU backend ``interpret=False`` compiles to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.persample_gradnorm import persample_gradnorm_pallas
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.rwkv_scan import wkv_pallas
from repro.kernels.wemd_swap import wemd_add_pallas, wemd_swap_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q [B,H,S,hd] layout (kernel layout; models use [B,S,H,hd])."""
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=_interpret())


def wkv(r, k, v, w, u):
    return wkv_pallas(r, k, v, w, u, interpret=_interpret())


def rglru(a, b, h0):
    return rglru_pallas(a, b, h0, interpret=_interpret())


def persample_gradnorm_sigma(features, logits, labels):
    sigma, _ = persample_gradnorm_pallas(features, logits, labels,
                                         interpret=_interpret())
    return sigma


def wemd_swap(p_sum, p_dev, global_dist, class_weights, sizes):
    """Dense [B,V,V] swap-candidate WEMD matrix (FSCD inner loop)."""
    return wemd_swap_pallas(p_sum, p_dev, global_dist, class_weights,
                            sizes, interpret=_interpret())


def wemd_add(p_sum, p_dev, global_dist, class_weights, sizes):
    """[B,V] add-candidate WEMD row (GS inner loop)."""
    return wemd_add_pallas(p_sum, p_dev, global_dist, class_weights,
                           sizes, interpret=_interpret())


__all__ = ["attention", "wkv", "rglru", "persample_gradnorm_sigma",
           "wemd_swap", "wemd_add",
           "flash_attention", "wkv_pallas", "rglru_pallas",
           "persample_gradnorm_pallas", "wemd_swap_pallas",
           "wemd_add_pallas", "ref"]
