"""Jit'd public wrappers over the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes as Python/jnp, validating the exact TPU code path.
On a real TPU backend ``interpret=False`` compiles to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.persample_gradnorm import persample_gradnorm_pallas
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.rwkv_scan import wkv_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q [B,H,S,hd] layout (kernel layout; models use [B,S,H,hd])."""
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=_interpret())


def wkv(r, k, v, w, u):
    return wkv_pallas(r, k, v, w, u, interpret=_interpret())


def rglru(a, b, h0):
    return rglru_pallas(a, b, h0, interpret=_interpret())


def persample_gradnorm_sigma(features, logits, labels):
    sigma, _ = persample_gradnorm_pallas(features, logits, labels,
                                         interpret=_interpret())
    return sigma


__all__ = ["attention", "wkv", "rglru", "persample_gradnorm_sigma",
           "flash_attention", "wkv_pallas", "rglru_pallas",
           "persample_gradnorm_pallas", "ref"]
