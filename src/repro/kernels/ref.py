"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are deliberately naive (dense masks, step-by-step recurrences):
correctness first, speed irrelevant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """q [B,H,S,hd], k/v [B,H,T,hd] -> [B,H,S,hd]. Dense masked softmax."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok &= ki <= qi + (T - S)          # right-aligned when T > S
    if window > 0:
        ok &= ki > qi + (T - S) - window
    scores = jnp.where(ok, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def wkv_ref(r, k, v, w, u, state=None):
    """RWKV6 naive recurrence. r,k,v,w [B,T,H,hd]; u [H,hd];
    state [B,H,hd,hd] f32. Returns (y [B,T,H,hd], final_state)."""
    B, T, H, hd = r.shape
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)
    rf, kf, vf, wf = (a.astype(jnp.float32).transpose(1, 0, 2, 3)
                      for a in (r, k, v, w))                # [T,B,H,hd]
    uf = u.astype(jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs
        y = jnp.einsum("bhd,bhdv->bhv", rt, S) \
            + jnp.einsum("bhd,hd,bhd->bh", rt, uf, kt)[..., None] * vt
        S = wt[..., None] * S + jnp.einsum("bhd,bhv->bhdv", kt, vt)
        return S, y

    final, ys = jax.lax.scan(step, state, (rf, kf, vf, wf))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), final


def rglru_ref(a, b, h0):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + b_t, step by step.
    a, b [B,T,D] f32; h0 [B,D]. Returns (h [B,T,D], h_T)."""
    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    final, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2),
                                        b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2), final


def wemd_swap_ref(p_sum, p_dev, global_dist, class_weights, sizes):
    """Batched dense swap-candidate WEMD matrix (paper Eq. 8 applied to
    Pi \\ {i} u {j} for every pair).  p_sum [B,C], p_dev [B,V,C],
    global_dist/class_weights [B,C], sizes [B] -> [B,V,V]."""
    base = (p_sum[:, None, None, :] - p_dev[:, :, None, :]) \
        + p_dev[:, None, :, :]
    dist = base / sizes[:, None, None, None]
    return jnp.sum(jnp.abs(dist - global_dist[:, None, None, :])
                   * class_weights[:, None, None, :], axis=-1)


def wemd_add_ref(p_sum, p_dev, global_dist, class_weights, sizes):
    """Batched add-candidate WEMD row (Pi u {v} for every v).
    Same layouts as ``wemd_swap_ref``; returns [B, V]."""
    new = (p_sum[:, None, :] + p_dev) / (sizes[:, None, None] + 1.0)
    return jnp.sum(jnp.abs(new - global_dist[:, None, :])
                   * class_weights[:, None, :], axis=-1)


def persample_gradnorm_ref(features, logits, labels):
    """sigma-hat (Eq. 10) for a softmax-CE linear head, materializing the
    full per-sample gradient tensor [B, d, C] (the thing the kernel
    avoids).  Returns (sigma, gi_sq [B])."""
    h = features.astype(jnp.float32)
    p = jax.nn.softmax(logits.astype(jnp.float32), -1)
    e = p - jax.nn.one_hot(labels, logits.shape[-1])
    g = h[:, :, None] * e[:, None, :]                  # [B, d, C]
    gbar = g.mean(0)
    dev = g - gbar[None]
    dev_sq = (dev * dev).sum((1, 2))
    return jnp.sqrt(dev_sq.mean()), (g * g).sum((1, 2))
