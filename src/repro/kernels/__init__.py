"""Pallas TPU kernels (validated via interpret=True on CPU).

  flash_attention     causal + sliding-window attention (MXU-tiled)
  rwkv_scan           RWKV6 chunked WKV recurrence, log-space decays
  rglru_scan          RG-LRU diagonal linear recurrence, sequential grid
  persample_gradnorm  fused FedCGD sigma-hat (Eq. 10) for softmax-CE heads

ops.py exposes jit'd wrappers; ref.py the pure-jnp oracles.
"""
from repro.kernels import ops, ref  # noqa: F401
