"""FlashAttention for TPU in Pallas: causal + sliding-window.

Tiling: grid (batch*heads, num_q_blocks, num_kv_blocks); the kv axis is
the innermost (sequential) grid dim, so the online-softmax running
statistics (m, l) and the output accumulator live in VMEM scratch and are
carried across kv steps.  Block shapes are MXU-aligned (q/kv blocks of
128, head_dim padded to a multiple of 128 by the wrapper in ops.py).

VMEM working set per step: q-block [Bq, hd] + k/v blocks [Bk, hd] +
scores [Bq, Bk] + acc [Bq, hd] — with Bq=Bk=128, hd<=256 that is
~0.5 MB << 16 MB VMEM, leaving room for double buffering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, seq_q: int, seq_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (seq_k - seq_q)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    mask = k_pos < seq_k
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q [B,H,S,hd], k/v [B,H,T,hd] -> [B,H,S,hd].

    When T > S the query block is right-aligned (decode-style suffix
    queries), matching ref.attention_ref."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, T)

    pad_q = (-S) % block_q
    pad_k = (-T) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sp, Tp = S + pad_q, T + pad_k

    qf = q.reshape(B * H, Sp, hd)
    kf = k.reshape(B * H, Tp, hd)
    vf = v.reshape(B * H, Tp, hd)

    grid = (B * H, Sp // block_q, Tp // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_q=S, seq_k=T)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sp, hd)[:, :, :S]
