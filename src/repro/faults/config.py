"""Fault-model knobs for the resilient round loop.

The paper's P1 schedules devices assuming every scheduled upload lands
within the deadline d_cm, but its own channel model (TR 38.901 shadow
fading, Eq. 9 infeasibility) implies real rounds lose uploads.
``FaultConfig`` describes, per round, which wireless/device failures are
injected and which server-side defenses are armed.  All draws are made
from a per-round seeded generator (seeded by ``(trainer seed, fault
seed, round index)``), so runs are bitwise reproducible and independent
of scheduling decisions.

With every probability at zero (the default) the fault layer is inert:
no random draws are made and ``FederatedTrainer`` reproduces the
fault-free round loop bitwise.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

CORRUPT_MODES = ("nan", "inf", "explode")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    # --- injection knobs (client / channel side) -----------------------
    # Bernoulli upload outage per scheduled device (blanket model).
    outage_prob: float = 0.0
    # Std (dB) of a *second* shadow-fading draw at upload time; a
    # degraded gain that pushes the achievable rate at the allocated
    # bandwidth below Eq. 9's requirement is a deadline miss -> outage.
    reshadow_std_db: float = 0.0
    # Fractional deadline slack tolerated before a re-shadowed upload
    # counts as an outage (0 = the strict Eq. 9 equality allocation).
    outage_slack: float = 0.0
    # Mid-round device dropout: the device computes and is scheduled,
    # then vanishes before upload (battery, mobility, churn).  Dropped
    # devices are also excluded from backfill.
    dropout_prob: float = 0.0
    # Compute-straggler deadline miss: the local update overruns its
    # compute budget and the upload never starts.
    deadline_miss_prob: float = 0.0
    # Corrupted delta: the upload arrives (consuming bandwidth) but its
    # payload is damaged — NaN/Inf leaves or a norm-exploded delta.
    corrupt_prob: float = 0.0
    corrupt_modes: Tuple[str, ...] = CORRUPT_MODES
    corrupt_scale: float = 1e8          # multiplier for "explode" mode
    # Extra seed folded into the per-round fault stream (lets two runs
    # share a trainer seed but draw different fault realisations).
    seed: int = 0

    # --- server-side defenses ------------------------------------------
    # Per-device delta L2-norm clip applied before Eq. 2 (0 = off).
    # The NaN/Inf guard is always on: non-finite deltas never aggregate.
    clip_delta_norm: float = 0.0
    # One-shot backfill: after upload failures, re-solve P1 over the
    # surviving feasible devices with the residual bandwidth budget.
    backfill: bool = True
    # On zero-upload rounds, sigma-hat / G-hat decay toward their priors
    # with this factor instead of freezing stale estimates.
    estimate_decay: float = 0.5

    def __post_init__(self):
        for name in ("outage_prob", "dropout_prob", "deadline_miss_prob",
                     "corrupt_prob"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if self.reshadow_std_db < 0:
            raise ValueError("reshadow_std_db must be >= 0")
        if not self.corrupt_modes:
            raise ValueError("corrupt_modes must be non-empty")
        unknown = set(self.corrupt_modes) - set(CORRUPT_MODES)
        if unknown:
            raise ValueError(f"unknown corrupt modes: {sorted(unknown)}")
        if not 0.0 <= self.estimate_decay <= 1.0:
            raise ValueError("estimate_decay must be in [0, 1]")

    @property
    def injection_enabled(self) -> bool:
        """True when any fault can actually fire this run."""
        return (self.outage_prob > 0 or self.reshadow_std_db > 0
                or self.dropout_prob > 0 or self.deadline_miss_prob > 0
                or self.corrupt_prob > 0)
