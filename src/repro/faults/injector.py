"""Per-round wireless fault injection.

``FaultInjector.draw(j)`` realises one round's faults for every device
from a generator seeded by ``(trainer seed, fault seed, round index)``:
the draws do not consume the trainer's RNG stream and do not depend on
which devices end up available or scheduled, so histories are bitwise
reproducible and faults can be evaluated lazily per device.

Failure-cause precedence for a scheduled upload:
    dropout > deadline (compute straggler) > outage (channel) > corrupt
The first three are *arrival* failures (the upload never lands and its
bandwidth is reclaimable by the backfill pass); "corrupt" uploads do
arrive — the server-side sanitizer decides their fate.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bandwidth import deadline_met
from repro.faults.config import FaultConfig
from repro.wireless.channel import apply_shadow_db

# Arrival-failure causes + corruption, in precedence order.
FAILURE_CAUSES = ("dropout", "deadline", "outage", "corrupt")


@dataclasses.dataclass
class RoundFaults:
    """One round's fault realisation over all V devices."""
    dropout: np.ndarray        # [V] bool
    deadline_miss: np.ndarray  # [V] bool
    outage: np.ndarray         # [V] bool — blanket Bernoulli outage
    reshadow_db: np.ndarray    # [V] float — second shadow draw (dB)
    corrupt: np.ndarray        # [V] bool
    corrupt_mode: np.ndarray   # [V] int — index into cfg.corrupt_modes

    @classmethod
    def none(cls, num_devices: int) -> "RoundFaults":
        z = np.zeros(num_devices, dtype=bool)
        return cls(dropout=z, deadline_miss=z.copy(), outage=z.copy(),
                   reshadow_db=np.zeros(num_devices),
                   corrupt=z.copy(),
                   corrupt_mode=np.zeros(num_devices, dtype=np.int64))


class FaultInjector:
    def __init__(self, cfg: FaultConfig, num_devices: int, base_seed: int):
        self.cfg = cfg
        self.num_devices = num_devices
        self.base_seed = base_seed

    @property
    def enabled(self) -> bool:
        return self.cfg.injection_enabled

    # ------------------------------------------------------------------
    def draw(self, round_idx: int) -> RoundFaults:
        """Realise round ``round_idx``'s faults (all-clear when inert)."""
        if not self.enabled:
            return RoundFaults.none(self.num_devices)
        cfg = self.cfg
        V = self.num_devices
        rng = np.random.default_rng(
            [0xFA017, self.base_seed, cfg.seed, round_idx])
        return RoundFaults(
            dropout=rng.random(V) < cfg.dropout_prob,
            deadline_miss=rng.random(V) < cfg.deadline_miss_prob,
            outage=rng.random(V) < cfg.outage_prob,
            reshadow_db=(rng.normal(0.0, cfg.reshadow_std_db, V)
                         if cfg.reshadow_std_db > 0 else np.zeros(V)),
            corrupt=rng.random(V) < cfg.corrupt_prob,
            corrupt_mode=rng.integers(0, len(cfg.corrupt_modes), V),
        )

    # ------------------------------------------------------------------
    def upload_gains(self, gains: np.ndarray, rf: RoundFaults) -> np.ndarray:
        """Channel gains as seen at upload time (second shadow draw)."""
        if self.cfg.reshadow_std_db <= 0:
            return gains
        return apply_shadow_db(gains, rf.reshadow_db)

    def arrival_failures(self, rf: RoundFaults, scheduled: np.ndarray,
                         alloc_bw: np.ndarray, data_bits: float,
                         deadline_s: float, upload_rx_power: np.ndarray,
                         noise_psd: float) -> np.ndarray:
        """Per-device arrival-failure cause ("" = the upload lands).

        ``scheduled``/``alloc_bw``/``upload_rx_power`` are [V] global
        arrays; alloc_bw is the bandwidth granted at scheduling time
        (Eq. 9's B* under the scheduling-time gains).
        """
        cause = np.full(self.num_devices, "", dtype=object)
        sched = np.asarray(scheduled, dtype=bool)
        if not self.enabled or not sched.any():
            return cause
        cause[sched & rf.dropout] = "dropout"
        free = sched & (cause == "")
        cause[free & rf.deadline_miss] = "deadline"
        free = sched & (cause == "")
        out = free & rf.outage
        if self.cfg.reshadow_std_db > 0:
            met = deadline_met(alloc_bw, data_bits, deadline_s,
                               upload_rx_power, noise_psd,
                               slack=self.cfg.outage_slack)
            out |= free & ~met
        cause[out] = "outage"
        return cause

    # ------------------------------------------------------------------
    def corrupt_delta(self, delta, mode: str):
        """Damage one device's model delta (pytree) in the given mode."""
        import jax
        import jax.numpy as jnp
        if mode == "nan":
            return jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), delta)
        if mode == "inf":
            return jax.tree.map(lambda x: jnp.full_like(x, jnp.inf), delta)
        if mode == "explode":
            s = self.cfg.corrupt_scale
            return jax.tree.map(lambda x: x * jnp.asarray(s, x.dtype), delta)
        raise ValueError(f"unknown corrupt mode {mode!r}")

    def corrupt_mode_of(self, rf: RoundFaults, v: int) -> str:
        return self.cfg.corrupt_modes[
            int(rf.corrupt_mode[v]) % len(self.cfg.corrupt_modes)]
