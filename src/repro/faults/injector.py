"""Per-round wireless fault injection.

``FaultInjector.draw(j)`` realises one round's faults for every device
from a generator seeded by ``(trainer seed, fault seed, round index)``:
the draws do not consume the trainer's RNG stream and do not depend on
which devices end up available or scheduled, so histories are bitwise
reproducible and faults can be evaluated lazily per device.

Failure-cause precedence for a scheduled upload:
    dropout > deadline (compute straggler) > outage (channel) > corrupt
The first three are *arrival* failures (the upload never lands and its
bandwidth is reclaimable by the backfill pass); "corrupt" uploads do
arrive — the server-side sanitizer decides their fate.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.core.bandwidth import deadline_met
from repro.faults.config import FaultConfig
from repro.wireless.channel import apply_shadow_db

# Arrival-failure causes + corruption, in precedence order.
FAILURE_CAUSES = ("dropout", "deadline", "outage", "corrupt")


@dataclasses.dataclass
class RoundFaults:
    """One round's fault realisation over all V devices."""
    dropout: np.ndarray        # [V] bool
    deadline_miss: np.ndarray  # [V] bool
    outage: np.ndarray         # [V] bool — blanket Bernoulli outage
    reshadow_db: np.ndarray    # [V] float — second shadow draw (dB)
    corrupt: np.ndarray        # [V] bool
    corrupt_mode: np.ndarray   # [V] int — index into cfg.corrupt_modes

    @classmethod
    def none(cls, num_devices: int) -> "RoundFaults":
        z = np.zeros(num_devices, dtype=bool)
        return cls(dropout=z, deadline_miss=z.copy(), outage=z.copy(),
                   reshadow_db=np.zeros(num_devices),
                   corrupt=z.copy(),
                   corrupt_mode=np.zeros(num_devices, dtype=np.int64))


class FaultInjector:
    def __init__(self, cfg: FaultConfig, num_devices: int, base_seed: int,
                 obs=None):
        self.cfg = cfg
        self.num_devices = num_devices
        self.base_seed = base_seed
        # optional repro.obs facade: injected-fault counters (set by the
        # trainers; None or a disabled facade = no telemetry)
        self.obs = obs

    @property
    def enabled(self) -> bool:
        return self.cfg.injection_enabled

    def _count_injected(self, rf: "RoundFaults") -> None:
        """Mirror one realisation into the metrics registry (host-side
        numpy sums only — never on the obs-disabled path)."""
        m = self.obs.metrics
        m.counter("faults.rounds_drawn").inc()
        for name, arr in (("dropout", rf.dropout),
                          ("deadline", rf.deadline_miss),
                          ("outage", rf.outage), ("corrupt", rf.corrupt)):
            n = int(arr.sum())
            if n:
                m.counter(f"faults.injected.{name}").inc(n)

    def _raw_draw(self, round_idx: int):
        """One round's raw RNG arrays, in ``draw``'s exact consumption
        order (uniforms for dropout/deadline/outage, the reshadow
        normals, the corrupt uniforms and mode integers)."""
        cfg = self.cfg
        V = self.num_devices
        rng = np.random.default_rng(
            [0xFA017, self.base_seed, cfg.seed, round_idx])
        u_drop = rng.random(V)
        u_dead = rng.random(V)
        u_out = rng.random(V)
        reshadow = (rng.normal(0.0, cfg.reshadow_std_db, V)
                    if cfg.reshadow_std_db > 0 else np.zeros(V))
        u_cor = rng.random(V)
        mode = rng.integers(0, len(cfg.corrupt_modes), V)
        return u_drop, u_dead, u_out, reshadow, u_cor, mode

    # ------------------------------------------------------------------
    def draw(self, round_idx: int) -> RoundFaults:
        """Realise round ``round_idx``'s faults (all-clear when inert)."""
        if not self.enabled:
            return RoundFaults.none(self.num_devices)
        cfg = self.cfg
        u_drop, u_dead, u_out, reshadow, u_cor, mode = \
            self._raw_draw(round_idx)
        rf = RoundFaults(
            dropout=u_drop < cfg.dropout_prob,
            deadline_miss=u_dead < cfg.deadline_miss_prob,
            outage=u_out < cfg.outage_prob,
            reshadow_db=reshadow,
            corrupt=u_cor < cfg.corrupt_prob,
            corrupt_mode=mode,
        )
        if self.obs is not None and self.obs.enabled:
            self._count_injected(rf)
        return rf

    @staticmethod
    def draw_many(injectors: Sequence["FaultInjector"],
                  round_idx: int) -> List[RoundFaults]:
        """One round's faults for C injectors with O(1) vectorized
        threshold passes over stacked [C, V] draws.

        Each injector's raw uniforms/normals still come from its own
        ``(seed, round)``-keyed generator in ``draw``'s order, so every
        cell's realisation is bitwise-identical to a standalone
        ``draw`` call; only the post-draw comparisons are batched.  The
        all-inert case allocates one [C, V] zero block shared by every
        cell instead of C sets of per-cell arrays."""
        C = len(injectors)
        V = injectors[0].num_devices
        if not any(inj.enabled for inj in injectors):
            zb = np.zeros((C, V), dtype=bool)
            zf = np.zeros((C, V))
            zi = np.zeros((C, V), dtype=np.int64)
            return [RoundFaults(dropout=zb[c], deadline_miss=zb[c],
                                outage=zb[c], reshadow_db=zf[c],
                                corrupt=zb[c], corrupt_mode=zi[c])
                    for c in range(C)]
        raws = [inj._raw_draw(round_idx) if inj.enabled
                else (np.ones(V), np.ones(V), np.ones(V), np.zeros(V),
                      np.ones(V), np.zeros(V, dtype=np.int64))
                for inj in injectors]
        u = [np.stack(cols) for cols in zip(*raws)]       # 6 x [C, V]
        prob = np.array([[inj.cfg.dropout_prob, inj.cfg.deadline_miss_prob,
                          inj.cfg.outage_prob, inj.cfg.corrupt_prob]
                         for inj in injectors])           # [C, 4]
        drop = u[0] < prob[:, 0:1]
        dead = u[1] < prob[:, 1:2]
        out = u[2] < prob[:, 2:3]
        cor = u[4] < prob[:, 3:4]
        rfs = [RoundFaults(dropout=drop[c], deadline_miss=dead[c],
                           outage=out[c], reshadow_db=u[3][c],
                           corrupt=cor[c], corrupt_mode=u[5][c])
               for c in range(C)]
        for inj, rf in zip(injectors, rfs):
            if inj.enabled and inj.obs is not None and inj.obs.enabled:
                inj._count_injected(rf)
        return rfs

    # ------------------------------------------------------------------
    def upload_gains(self, gains: np.ndarray, rf: RoundFaults) -> np.ndarray:
        """Channel gains as seen at upload time (second shadow draw)."""
        if self.cfg.reshadow_std_db <= 0:
            return gains
        return apply_shadow_db(gains, rf.reshadow_db)

    def arrival_failures(self, rf: RoundFaults, scheduled: np.ndarray,
                         alloc_bw: np.ndarray, data_bits: float,
                         deadline_s: float, upload_rx_power: np.ndarray,
                         noise_psd: float) -> np.ndarray:
        """Per-device arrival-failure cause ("" = the upload lands).

        ``scheduled``/``alloc_bw``/``upload_rx_power`` are [V] global
        arrays; alloc_bw is the bandwidth granted at scheduling time
        (Eq. 9's B* under the scheduling-time gains).
        """
        cause = np.full(self.num_devices, "", dtype=object)
        sched = np.asarray(scheduled, dtype=bool)
        if not self.enabled or not sched.any():
            return cause
        cause[sched & rf.dropout] = "dropout"
        free = sched & (cause == "")
        cause[free & rf.deadline_miss] = "deadline"
        free = sched & (cause == "")
        out = free & rf.outage
        if self.cfg.reshadow_std_db > 0:
            met = deadline_met(alloc_bw, data_bits, deadline_s,
                               upload_rx_power, noise_psd,
                               slack=self.cfg.outage_slack)
            out |= free & ~met
        cause[out] = "outage"
        return cause

    # ------------------------------------------------------------------
    def corrupt_delta(self, delta, mode: str):
        """Damage one device's model delta (pytree) in the given mode."""
        import jax
        import jax.numpy as jnp
        if mode == "nan":
            return jax.tree.map(lambda x: jnp.full_like(x, jnp.nan), delta)
        if mode == "inf":
            return jax.tree.map(lambda x: jnp.full_like(x, jnp.inf), delta)
        if mode == "explode":
            s = self.cfg.corrupt_scale
            return jax.tree.map(lambda x: x * jnp.asarray(s, x.dtype), delta)
        raise ValueError(f"unknown corrupt mode {mode!r}")

    def corrupt_mode_of(self, rf: RoundFaults, v: int) -> str:
        return self.cfg.corrupt_modes[
            int(rf.corrupt_mode[v]) % len(self.cfg.corrupt_modes)]
