"""Server-side delta sanitization (the defense half of the fault layer).

Before Eq. 2 aggregation every arrived upload is screened:
  * NaN/Inf guard (always on): a delta with any non-finite leaf is
    dropped — averaging it would poison the global model irreversibly.
  * Norm clip (``FaultConfig.clip_delta_norm`` > 0): a delta whose L2
    norm exceeds the clip is rescaled onto the clip ball and kept.

The sanitizer never mutates the stacked device parameters; it reports
which uploads survive and returns replacement deltas only for the ones
it modified, so a clean round leaves the aggregation inputs bitwise
untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.estimation import tree_norm


@dataclasses.dataclass
class SanitizeResult:
    kept: List[int]                 # upload indices entering aggregation
    dropped_nonfinite: List[int]    # uploads rejected by the NaN/Inf guard
    clipped: List[int]              # uploads rescaled onto the clip ball
    deltas: Dict[int, object]       # index -> replacement delta pytree

    @property
    def num_sanitized(self) -> int:
        return len(self.dropped_nonfinite) + len(self.clipped)


def finite_per_device(stacked) -> np.ndarray:
    """[A] bool: device i's leaves are all finite (one vectorized pass)."""
    import jax
    import jax.numpy as jnp
    leaves = jax.tree.leaves(stacked)
    flags = [jnp.isfinite(x).reshape(x.shape[0], -1).all(axis=1)
             for x in leaves]
    out = flags[0]
    for f in flags[1:]:
        out = out & f
    return np.asarray(out)


def tree_is_finite(tree) -> bool:
    import jax
    import jax.numpy as jnp
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree))


def sanitize_updates(deltas, upload_idx: Sequence[int],
                     overrides: Dict[int, object], clip_norm: float,
                     norms: Optional[np.ndarray] = None,
                     finite: Optional[np.ndarray] = None) -> SanitizeResult:
    """Screen the uploads in ``upload_idx``.

    ``deltas`` is the stacked [A, ...] delta pytree; ``overrides`` maps
    an index to a replacement (e.g. corrupted) delta that shadows the
    stacked row; ``norms`` optionally carries precomputed L2 norms for
    the unmodified rows.  ``finite`` optionally carries precomputed
    per-row NaN/Inf-guard flags (the fused round core emits them), in
    which case a clean round makes no device round-trip at all.
    """
    import jax
    upload_idx = [int(i) for i in upload_idx]
    res = SanitizeResult(kept=[], dropped_nonfinite=[], clipped=[],
                         deltas=dict(overrides))
    if not upload_idx:
        return res
    plain = [i for i in upload_idx if i not in overrides]
    finite_map = {}
    if plain:
        fin = finite if finite is not None else finite_per_device(deltas)
        finite_map.update({i: bool(fin[i]) for i in plain})
    for i in upload_idx:
        delta = res.deltas.get(i)
        ok = tree_is_finite(delta) if delta is not None else finite_map[i]
        if not ok:
            res.dropped_nonfinite.append(i)
            res.deltas.pop(i, None)
            continue
        if clip_norm > 0:
            if delta is not None:
                norm = float(tree_norm(delta))
            elif norms is not None:
                norm = float(norms[i])
            else:
                norm = float(tree_norm(
                    jax.tree.map(lambda x: x[i], deltas)))
            if norm > clip_norm:
                if delta is None:
                    delta = jax.tree.map(lambda x: x[i], deltas)
                scale = clip_norm / norm
                res.deltas[i] = jax.tree.map(
                    lambda x: x * np.asarray(scale, x.dtype), delta)
                res.clipped.append(i)
        res.kept.append(i)
    return res
