"""Wireless fault injection + server-side defenses for the round loop.

See ``repro.faults.config.FaultConfig`` for the knobs,
``repro.faults.injector.FaultInjector`` for the per-round realisation,
and ``repro.faults.sanitize`` for the delta screening applied before
Eq. 2 aggregation.
"""
from repro.faults.config import CORRUPT_MODES, FaultConfig  # noqa: F401
from repro.faults.injector import (FAILURE_CAUSES, FaultInjector,  # noqa: F401
                                   RoundFaults)
from repro.faults.sanitize import (SanitizeResult, finite_per_device,  # noqa: F401
                                   sanitize_updates, tree_is_finite)
