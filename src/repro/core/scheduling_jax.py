"""Batched JAX backend for the P1 schedulers (GS / FSCD).

``solve_many_jax`` replicates the numpy solvers' arithmetic op-for-op in
float64 (inside a local ``jax.experimental.enable_x64`` scope) so its
masks coincide with the per-problem numpy path, while amortizing the
solver over a problem axis:

  * GS (Algorithm 1) runs all problems through one jitted while-loop —
    each iteration adds at most one device per problem.
  * FSCD (Algorithm 2) is vectorized over problems *and* over the
    fix-sum axis: every (problem, S) pair of the outer loop is an
    independent lane of a coordinate-descent while-loop, run in short
    *phases* — after each phase the still-unconverged lanes are
    compacted on the host so the batch shrinks as lanes converge.  The
    swap matrix is scanned on member-compacted rows in float32 and the
    top-K candidates re-evaluated with numpy's exact float64 op order,
    with ties broken by device index exactly like ``np.argmin``.  The
    ``best``/early-exit bookkeeping of the numpy outer loop is replayed
    on the host from per-lane results.

The float64 decisions make this the parity backend on CPU.  The float32
Pallas kernels in ``repro.kernels`` (``wemd_swap`` / ``wemd_add``) can
be routed in for the candidate *scan* (``pallas=True``, or automatically
on a TPU backend): the kernels produce the f32 swap/add matrices and the
exact-f64 top-K re-evaluation still makes every accept/swap decision, so
the selected masks remain bitwise-equal to numpy.  On a single
CPU core the batched FSCD path roughly matches the numpy loop (the
lanes are data-parallel, so the win scales with cores/accelerator);
batched GS is ~8x even single-core.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core import scheduling as SCH

_LANE_BUCKET = 32          # min lane-batch granule (see _bucket)


def _enable_x64():
    from jax.experimental import enable_x64
    return enable_x64()


def _use_pallas(pallas) -> bool:
    """Kernel routing: explicit override wins, else auto-on-TPU.  The
    Pallas kernels compute the f32 candidate matrices; ranking still goes
    through the exact-f64 top-K re-evaluation, so the selected masks stay
    bitwise-equal to the numpy solvers (verified in tests with the
    interpret-mode kernels on CPU)."""
    if pallas is not None:
        return bool(pallas)
    import jax
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Algorithm 1 (GS), batched over problems


def _gs_batch_impl(p_dev, gd, cw, sigma, batch_size, min_bw, total_bw,
                   use_pallas=False):
    import jax
    import jax.numpy as jnp

    B, V, C = p_dev.shape
    feas = (min_bw >= 0) & (min_bw <= total_bw[:, None])
    sigma_b = sigma / jnp.sqrt(batch_size)
    K = min(16, V)

    def cond(carry):
        return carry[4].any()

    def body(carry):
        mask, p_sum, used, w_cur, active, iters = carry
        cand = feas & ~mask & (min_bw <= (total_bw - used)[:, None] + 1e-9)
        act = active & cand.any(axis=1)
        iters = iters + act.astype(jnp.int32)
        size = jnp.sum(mask, axis=1).astype(p_dev.dtype)
        if use_pallas:
            # f32 Pallas add-candidate row, then exact f64 (numpy op
            # order) re-evaluation of the top K — ranking error ~1e-6
            # vs candidate gaps O(1e-3), so the true argmin is inside K
            from repro.kernels import ops
            w32 = ops.wemd_add(p_sum, p_dev, gd, cw, size)
            w32 = jnp.where(cand, w32, jnp.float32(jnp.inf))
            _, topk = jax.lax.top_k(-w32, K)                     # [B,K]
            p_k = jnp.take_along_axis(p_dev, topk[:, :, None], 1)
            new_k = (p_sum[:, None, :] + p_k) / (size[:, None, None] + 1.0)
            w64k = jnp.einsum("bkc,bc->bk",
                              jnp.abs(new_k - gd[:, None, :]), cw)
            valid_k = jnp.take_along_axis(cand, topk, 1)
            w64k = jnp.where(valid_k, w64k, jnp.inf)
            wk = w64k.min(axis=1)
            # numpy argmin tie-break: min device index among exact minima
            k = jnp.minimum(
                jnp.where(valid_k & (w64k == wk[:, None]), topk,
                          V).min(axis=1), V - 1)
        else:
            # wemd_add_candidates, batched, all-f64
            new = (p_sum[:, None, :] + p_dev) / (size[:, None, None] + 1.0)
            w_new = jnp.einsum("bvc,bc->bv",
                               jnp.abs(new - gd[:, None, :]), cw)
            w_new = jnp.where(cand, w_new, jnp.inf)
            k = jnp.argmin(w_new, axis=1)
            wk = jnp.take_along_axis(w_new, k[:, None], 1)[:, 0]
        inv_sqrt = jnp.where(size > 0,
                             1.0 / jnp.sqrt(jnp.where(size > 0, size, 1.0)),
                             jnp.inf)
        sv_gain = sigma_b * (inv_sqrt - 1.0 / jnp.sqrt(size + 1.0))
        accept = (w_cur - wk) + sv_gain >= 0
        upd = act & accept
        sel = jnp.arange(V)[None, :] == k[:, None]
        mask = mask | (upd[:, None] & sel)
        pk = jnp.take_along_axis(p_dev, k[:, None, None], 1)[:, 0]
        p_sum = jnp.where(upd[:, None], p_sum + pk, p_sum)
        bwk = jnp.take_along_axis(min_bw, k[:, None], 1)[:, 0]
        used = jnp.where(upd, used + bwk, used)
        w_cur = jnp.where(upd, wk, w_cur)
        return mask, p_sum, used, w_cur, upd, iters

    init = (jnp.zeros((B, V), bool),
            jnp.zeros((B, C), p_dev.dtype),
            jnp.zeros((B,), p_dev.dtype),
            jnp.einsum("bc,bc->b", gd, cw),
            jnp.ones((B,), bool),
            jnp.zeros((B,), jnp.int32))
    mask, _, _, _, _, iters = jax.lax.while_loop(cond, body, init)
    return mask, iters


# ---------------------------------------------------------------------------
# Algorithm 2 (FSCD), one lane per (problem, S) pair
#
# The swap-candidate matrix is evaluated on member-compacted rows
# [L, S_cap, V] (S_cap = max fix-sum across lanes) instead of the full
# [L, V, V] grid, with the factored form
#     W[l,r,j] = sum_c cw_c * | A[l,r,c] + B[l,j,c] |,
#     A = (p_sum - p_member)/S - gd,   B = p_dev/S,
# and ties broken by the *device-order* flat index (min vi*V+vj among
# exact minima) — precisely numpy's argmin-over-[in,out] tie-break, so
# the member-row permutation cannot change the selected swap.


def _fscd_phase_impl(p_dev, gd, cw, bw, feas, total_bw, s_lane,
                     members, mask, p_sum, used, w_cur, act, iters,
                     max_inner, phase_steps, use_pallas=False):
    import jax
    import jax.numpy as jnp

    L, V, C = p_dev.shape
    R = members.shape[1]
    sf = s_lane.astype(p_dev.dtype)
    sf_safe = jnp.maximum(sf, 1.0)
    valid_r = jnp.arange(R)[None, :] < s_lane[:, None]

    def cond(carry):
        live = carry[5] & (carry[6] < max_inner)
        return live.any() & (carry[7] < phase_steps)

    K = min(16, R * V)
    f32 = jnp.float32

    def body(carry):
        members, mask, p_sum, used, w_cur, act, iters, step = carry
        live = act & (iters < max_inner)
        iters = iters + live.astype(jnp.int32)
        p_mem = jnp.take_along_axis(p_dev, members[:, :, None], 1)  # [L,R,C]
        # float32 scan of the full [R, V] swap matrix, then an exact
        # float64 re-evaluation (numpy's op order) of the K best
        # candidates — f32 ranking error is ~1e-6 while candidate gaps
        # are O(1e-3), so the true minimum is always inside the top K
        if use_pallas:
            # dense [L,V,V] f32 swap matrix from the Pallas kernel,
            # gathered down to the member rows
            from repro.kernels import ops
            w_dense = ops.wemd_swap(p_sum, p_dev, gd, cw, sf_safe)
            w32 = jnp.take_along_axis(w_dense, members[:, :, None], 1)
        else:
            a = ((p_sum[:, None, :] - p_mem) / sf_safe[:, None, None]
                 - gd[:, None, :]).astype(f32)
            b = (p_dev / sf_safe[:, None, None]).astype(f32)
            w32 = jnp.sum(jnp.abs(a[:, :, None, :] + b[:, None, :, :])
                          * cw[:, None, None, :].astype(f32),
                          axis=-1)                              # [L,R,V]
        bw_mem = jnp.take_along_axis(bw, members, 1)
        bw_new = (used[:, None, None] - bw_mem[:, :, None]) + bw[:, None, :]
        ok = valid_r[:, :, None] & (~mask & feas)[:, None, :] \
            & (bw_new <= total_bw[:, None, None] + 1e-9)
        wm32 = jnp.where(ok, w32, f32(jnp.inf)).reshape(L, R * V)
        _, flat_rv = jax.lax.top_k(-wm32, K)                     # [L,K]
        r_k = flat_rv // V
        j_k = flat_rv % V
        vi_k = jnp.take_along_axis(members, r_k, 1)
        p_i_k = jnp.take_along_axis(p_dev, vi_k[:, :, None], 1)  # [L,K,C]
        p_j_k = jnp.take_along_axis(p_dev, j_k[:, :, None], 1)
        base = (p_sum[:, None, :] - p_i_k) + p_j_k
        w64 = jnp.sum(jnp.abs(base / sf_safe[:, None, None]
                              - gd[:, None, :]) * cw[:, None, :], axis=-1)
        valid_k = jnp.take_along_axis(ok.reshape(L, R * V), flat_rv, 1)
        w64 = jnp.where(valid_k, w64, jnp.inf)
        wmin = w64.min(axis=1)
        # numpy tie-break: first (vi, vj) in device order among minima
        flat_dev = vi_k * jnp.int32(V) + j_k.astype(jnp.int32)
        flatmin = jnp.where(valid_k & (w64 == wmin[:, None]), flat_dev,
                            jnp.int32(V * V)).min(axis=1)
        vi = jnp.minimum(flatmin // V, V - 1)
        vj = jnp.minimum(flatmin % V, V - 1)
        rsel = (members == vi[:, None]) & valid_r
        rpos = jnp.argmax(rsel, axis=1)
        improve = wmin < w_cur - 1e-12
        upd = live & improve
        members = jnp.where(
            upd[:, None] & (jnp.arange(R)[None, :] == rpos[:, None]),
            vj[:, None], members)
        sel_i = jnp.arange(V)[None, :] == vi[:, None]
        sel_j = jnp.arange(V)[None, :] == vj[:, None]
        mask = jnp.where(upd[:, None], (mask & ~sel_i) | sel_j, mask)
        p_i = jnp.take_along_axis(p_dev, vi[:, None, None], 1)[:, 0]
        p_j = jnp.take_along_axis(p_dev, vj[:, None, None], 1)[:, 0]
        p_sum = jnp.where(upd[:, None], p_sum + (p_j - p_i), p_sum)
        bw_i = jnp.take_along_axis(bw, vi[:, None], 1)[:, 0]
        bw_j = jnp.take_along_axis(bw, vj[:, None], 1)[:, 0]
        used = jnp.where(upd, (used - bw_i) + bw_j, used)
        w_cur = jnp.where(upd, wmin, w_cur)
        return members, mask, p_sum, used, w_cur, upd, iters, step + 1

    init = (members, mask, p_sum, used, w_cur, act, iters,
            jnp.asarray(0, jnp.int32))
    out = jax.lax.while_loop(cond, body, init)
    return out[:7]


_JIT_CACHE: dict = {}


def _jitted(name, fn, static_argnums=()):
    import jax
    if name not in _JIT_CACHE:
        _JIT_CACHE[name] = jax.jit(fn, static_argnums=static_argnums)
    return _JIT_CACHE[name]


# ---------------------------------------------------------------------------
# host-side drivers


def _stack(problems: Sequence[SCH.Problem]):
    V = problems[0].num_devices
    C = problems[0].p_dev.shape[1]
    for p in problems:
        if p.p_dev.shape != (V, C):
            raise ValueError("solve_many requires same-shaped problems, got "
                             f"{p.p_dev.shape} vs {(V, C)}")
    return {
        "p_dev": np.stack([np.asarray(p.p_dev, np.float64)
                           for p in problems]),
        "gd": np.stack([np.asarray(p.global_dist, np.float64)
                        for p in problems]),
        "cw": np.stack([np.asarray(p.class_weights, np.float64)
                        for p in problems]),
        "sigma": np.array([p.sigma for p in problems], np.float64),
        "batch_size": np.array([p.batch_size for p in problems], np.float64),
        "min_bw": np.stack([np.asarray(p.min_bw, np.float64)
                            for p in problems]),
        "total_bw": np.array([p.total_bw for p in problems], np.float64),
    }


def solve_many_gs(problems: Sequence[SCH.Problem],
                  pallas: bool | None = None) -> List[SCH.Schedule]:
    st = _stack(problems)
    up = _use_pallas(pallas)
    with _enable_x64():
        fn = _jitted(f"gs_p{int(up)}", _gs_batch_impl,
                     static_argnums=(7,))
        masks, iters = fn(st["p_dev"], st["gd"], st["cw"], st["sigma"],
                          st["batch_size"], st["min_bw"], st["total_bw"],
                          up)
        masks, iters = np.asarray(masks), np.asarray(iters)
    return [SCH._make_schedule(p, masks[b], int(iters[b]), "GS")
            for b, p in enumerate(problems)]


def _bucket(n: int) -> int:
    # round up to a coarse-enough granule that recompilation stays rare
    # while padding waste stays ~<12%
    g = _LANE_BUCKET
    while g * 8 < n:
        g *= 2
    return -(-n // g) * g


def solve_many_fscd(problems: Sequence[SCH.Problem],
                    max_inner: int = 200,
                    phase_steps: int = 4,
                    pallas: bool | None = None) -> List[SCH.Schedule]:
    from repro.core import wemd as WE

    st = _stack(problems)
    B, V, C = st["p_dev"].shape

    # lane layout: per problem, one lane per S in range(S_max, 0, -1),
    # initialized with the numpy solver's exact host arithmetic
    feas_p = (st["min_bw"] >= 0) & (st["min_bw"] <= st["total_bw"][:, None])
    bw_p = np.where(feas_p, st["min_bw"], np.inf)
    order_p = np.argsort(bw_p, axis=1, kind="stable")
    s_max = np.zeros(B, int)
    for b in range(B):
        cum = np.cumsum(bw_p[b][order_p[b]])
        s_max[b] = int((cum <= st["total_bw"][b] + 1e-9).sum())
    lane_b = np.concatenate([np.full(s_max[b], b, int) for b in range(B)]
                            or [np.zeros(0, int)])
    s_lane = np.concatenate([np.arange(s_max[b], 0, -1) for b in range(B)]
                            or [np.zeros(0, int)])
    L = len(lane_b)

    masks = np.zeros((L, V), bool)
    w_cur = np.zeros(L)
    iters = np.zeros(L, np.int32)
    if L:
        S_cap = int(s_lane.max())
        members = np.zeros((L, S_cap), np.int32)
        p_sum = np.zeros((L, C))
        used = np.zeros(L)
        act = np.ones(L, bool)
        for l in range(L):
            b, S = lane_b[l], int(s_lane[l])
            members[l, :S] = order_p[b][:S]
            masks[l, order_p[b][:S]] = True
            p_sum[l] = st["p_dev"][b][masks[l]].sum(axis=0)
            used[l] = float(bw_p[b][order_p[b][:S]].sum())
            w_cur[l] = WE.wemd_of_set(st["p_dev"][b], masks[l], st["gd"][b],
                                      st["cw"][b])
        # lane-indexed constants
        p_dev_l, gd_l, cw_l = (st["p_dev"][lane_b], st["gd"][lane_b],
                               st["cw"][lane_b])
        bw_l, feas_l = bw_p[lane_b], feas_p[lane_b]
        tot_l = st["total_bw"][lane_b]

        # phase-chunked descent: run every live lane a few steps, pull
        # the still-live set to the host, compact, repeat — so the batch
        # shrinks as lanes converge instead of spinning until the
        # slowest lane is done
        alive = np.arange(L)
        up = _use_pallas(pallas)
        with _enable_x64():
            fn = _jitted(f"fscd_phase_p{int(up)}", _fscd_phase_impl,
                         static_argnums=(14, 15, 16))
            while alive.size:
                n = alive.size
                sel = np.concatenate(
                    [alive, np.full(_bucket(n) - n, alive[0])])
                act_in = act[sel]
                act_in[n:] = False
                out = fn(p_dev_l[sel], gd_l[sel], cw_l[sel], bw_l[sel],
                         feas_l[sel], tot_l[sel], s_lane[sel],
                         members[sel], masks[sel], p_sum[sel], used[sel],
                         w_cur[sel], act_in, iters[sel],
                         int(max_inner), int(phase_steps), up)
                o = [np.asarray(x)[:n] for x in out]
                members[alive], masks[alive], p_sum[alive] = o[0], o[1], o[2]
                used[alive], w_cur[alive] = o[3], o[4]
                act[alive], iters[alive] = o[5], o[6]
                alive = alive[o[5] & (o[6] < max_inner)]

    # replay the numpy outer loop (best tracking + early exit) exactly
    out: List[SCH.Schedule] = []
    lane0 = np.concatenate([[0], np.cumsum(s_max)])[:-1]
    for b, prob in enumerate(problems):
        sigma_b = prob.sigma / np.sqrt(prob.batch_size)
        best_mask, best_obj = np.zeros(V, bool), np.inf
        total_iters = 0
        for t, S in enumerate(range(s_max[b], 0, -1)):
            l = lane0[b] + t
            total_iters += int(iters[l])
            obj = w_cur[l] + sigma_b / np.sqrt(S)
            if obj < best_obj:
                best_obj, best_mask = obj, masks[l]
            if S > 1 and w_cur[l] + sigma_b / np.sqrt(S) \
                    <= sigma_b / np.sqrt(S - 1):
                break
        out.append(SCH._make_schedule(prob, best_mask, total_iters, "FSCD"))
    return out
