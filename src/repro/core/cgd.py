"""Collective-gradient-divergence instrumentation (paper Section IV).

These functions *measure* the quantities the theory bounds, so the
convergence story is testable:

  device_level_cgd   Delta^{(j)} = || sum alpha_v grad_v - grad_F ||  (Eq. 5)
  sample_level_bound sigma / sqrt(|Pi| b)                             (Lemma 2)
  local_iter_bias    0.5 tau (tau-1) eta beta g                       (Lemma 3)
  fc_difference      U_j = || w^{(j)} - v^{(j)} ||                    (Sec. IV)

fl/virtual.py maintains the virtual centralized model v^{(j)} these feed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimation import tree_norm, tree_sub, tree_weighted_sum


def device_level_cgd(device_grads, alphas, global_grad) -> jax.Array:
    """Eq. 5 — the *collective* divergence of the scheduled group.

    device_grads: list of grad pytrees for v in Pi; alphas: list of
    aggregation weights; global_grad: grad of the global objective."""
    agg = tree_weighted_sum(device_grads, list(np.asarray(alphas)))
    return tree_norm(tree_sub(agg, global_grad))


def individual_divergences(device_grads, global_grad) -> np.ndarray:
    """delta_v = ||grad_v - grad_F|| for each device (Remark 1: summing
    these does NOT give the collective divergence)."""
    return np.array([float(tree_norm(tree_sub(g, global_grad)))
                     for g in device_grads])


def sample_level_bound(sigma: float, num_scheduled: int,
                       batch_size: int) -> float:
    if num_scheduled <= 0:
        return float("inf")
    return sigma / np.sqrt(num_scheduled * batch_size)


def local_iter_bias_bound(tau: int, eta: float, beta: float, g: float) -> float:
    """Lemma 3: 0.5 * tau(tau-1) * eta * beta * g."""
    return 0.5 * tau * (tau - 1) * eta * beta * g


def fc_difference(w_agg, w_virtual) -> jax.Array:
    """U_j = ||w^{(j)} - v^{(j)}||."""
    return tree_norm(tree_sub(w_agg, w_virtual))


def theorem1_bound(delta: float, sigma: float, num_scheduled: int,
                   batch_size: int, tau: int, eta: float, beta: float,
                   g: float) -> float:
    """Theorem 1's bound on E[U_j]."""
    return (local_iter_bias_bound(tau, eta, beta, g)
            + eta * tau * (sample_level_bound(sigma, num_scheduled,
                                              batch_size) + delta))
