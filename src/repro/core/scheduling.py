"""Device-scheduling algorithms for P1 (paper Section V-B) + baselines.

The paper's solvers:
  * greedy_scheduling   — Algorithm 1, O(V^2)
  * fscd                — Algorithm 2, fix-sum coordinate descent, O(tV^2)
  * coordinate_descent  — the CD baseline of Fig. 3 (1-flip neighborhood)
  * exhaustive          — exact solver for small V (test oracle)

Baselines of Section VI-A:
  * best_channel (BC), best_norm (BN), power_of_choice (POC),
    fed_cbs (QCID-driven combinatorial-UCB sampling)

All solvers consume a ``Problem`` describing one round: per-device label
distributions, global distribution, class weights G_c, sigma, batch size,
per-device minimum bandwidth B_v* and the bandwidth budget B.

Batched engine
--------------
``solve_many(problems, algorithm, backend)`` solves many same-shaped
Problems at once.  ``backend="numpy"`` loops the per-problem solvers
above; ``backend="jax"`` (the default) stacks the problems into
[B, V, C] / [B, V] arrays and runs GS / FSCD through the vectorized
float64 engine in ``repro.core.scheduling_jax``, which reproduces the
numpy solvers' masks exactly while amortizing the whole batch (and,
for FSCD, the fix-sum axis S) over a single jitted loop.  The float32
Pallas kernels ``repro.kernels.ops.wemd_swap`` / ``wemd_add`` provide
the same swap/add matrices as device-resident primitives for TPU
deployments.  ``FederatedTrainer`` selects the backend through the
``FLConfig.scheduler_backend`` knob ("numpy" | "jax").
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core import wemd as WE


@dataclasses.dataclass
class Problem:
    p_dev: np.ndarray          # [V, C] device label distributions
    global_dist: np.ndarray    # [C]
    class_weights: np.ndarray  # [C] G_c  (or G * ones)
    sigma: float
    batch_size: int
    min_bw: np.ndarray         # [V] B_v* (Hz), -1 = infeasible
    total_bw: float            # B (Hz)

    @property
    def num_devices(self) -> int:
        return self.p_dev.shape[0]

    def feasible(self) -> np.ndarray:
        return (self.min_bw >= 0) & (self.min_bw <= self.total_bw)

    def objective(self, mask) -> float:
        return WE.p1_objective(mask, self.p_dev, self.global_dist,
                               self.class_weights, self.sigma,
                               self.batch_size)

    def bw_ok(self, mask) -> bool:
        mask = np.asarray(mask, bool)
        if np.any(mask & ~self.feasible()):
            return False
        return float(self.min_bw[mask].sum()) <= self.total_bw + 1e-9


@dataclasses.dataclass
class Schedule:
    mask: np.ndarray           # [V] bool
    objective: float
    wemd: float
    sampling_variance: float
    iterations: int = 0
    algorithm: str = ""

    @property
    def num_scheduled(self) -> int:
        return int(self.mask.sum())


def _make_schedule(prob: Problem, mask, iters, name) -> Schedule:
    mask = np.asarray(mask, bool)
    w = WE.wemd_of_set(prob.p_dev, mask, prob.global_dist,
                       prob.class_weights)
    sv = WE.sampling_variance(prob.sigma, int(mask.sum()), prob.batch_size)
    return Schedule(mask=mask, objective=w + sv, wemd=w,
                    sampling_variance=sv, iterations=iters, algorithm=name)


# ---------------------------------------------------------------------------
# Algorithm 1: Greedy Scheduling


def greedy_scheduling(prob: Problem) -> Schedule:
    V = prob.num_devices
    feas = prob.feasible()
    mask = np.zeros(V, bool)
    p_sum = np.zeros(prob.p_dev.shape[1])
    used_bw = 0.0
    sigma_b = prob.sigma / np.sqrt(prob.batch_size)
    w_cur = WE.wemd_of_set(prob.p_dev, mask, prob.global_dist,
                           prob.class_weights)
    iters = 0
    while True:
        cand = feas & ~mask & (prob.min_bw <= prob.total_bw - used_bw + 1e-9)
        if not cand.any():
            break
        iters += 1
        size = int(mask.sum())
        w_new = WE.wemd_add_candidates(p_sum, size, prob.p_dev,
                                       prob.global_dist, prob.class_weights)
        w_new = np.where(cand, w_new, np.inf)
        k = int(np.argmin(w_new))               # max WEMD reduction
        # sampling-variance gain of going S -> S+1
        sv_gain = sigma_b * ((1.0 / np.sqrt(size) if size else np.inf)
                             - 1.0 / np.sqrt(size + 1))
        if (w_cur - w_new[k]) + sv_gain >= 0:
            mask[k] = True
            p_sum += prob.p_dev[k]
            used_bw += prob.min_bw[k]
            w_cur = w_new[k]
        else:
            break
    return _make_schedule(prob, mask, iters, "GS")


# ---------------------------------------------------------------------------
# Algorithm 2: Fix-Sum Coordinate Descent


def fscd(prob: Problem, max_inner: int = 200) -> Schedule:
    V = prob.num_devices
    feas = prob.feasible()
    bw = np.where(feas, prob.min_bw, np.inf)
    order = np.argsort(bw, kind="stable")       # least bandwidth first
    sigma_b = prob.sigma / np.sqrt(prob.batch_size)

    best_mask, best_obj = np.zeros(V, bool), np.inf
    total_iters = 0
    # the largest feasible S: greedy-fill by least bandwidth
    cum = np.cumsum(bw[order])
    S_max = int((cum <= prob.total_bw + 1e-9).sum())

    for S in range(S_max, 0, -1):
        mask = np.zeros(V, bool)
        mask[order[:S]] = True
        p_sum = prob.p_dev[mask].sum(axis=0)
        used = float(bw[order[:S]].sum())
        w_cur = WE.wemd_of_set(prob.p_dev, mask, prob.global_dist,
                               prob.class_weights)
        for _ in range(max_inner):
            total_iters += 1
            in_idx = np.flatnonzero(mask)
            out_idx = np.flatnonzero(~mask & feas)
            if len(out_idx) == 0:
                break
            w_swap = WE.wemd_swap_candidates(
                p_sum, S, prob.p_dev, in_idx, out_idx,
                prob.global_dist, prob.class_weights)
            # bandwidth feasibility of each swap
            bw_new = used - bw[in_idx][:, None] + bw[out_idx][None, :]
            w_swap = np.where(bw_new <= prob.total_bw + 1e-9, w_swap, np.inf)
            i, j = np.unravel_index(np.argmin(w_swap), w_swap.shape)
            if w_swap[i, j] >= w_cur - 1e-12:
                break                            # local optimum
            vi, vj = in_idx[i], out_idx[j]
            mask[vi], mask[vj] = False, True
            p_sum += prob.p_dev[vj] - prob.p_dev[vi]
            used = float(bw_new[i, j])
            w_cur = float(w_swap[i, j])
        obj = w_cur + sigma_b / np.sqrt(S)
        if obj < best_obj:
            best_obj, best_mask = obj, mask.copy()
        # early exit (paper line 10): no smaller S can do better
        if S > 1 and w_cur + sigma_b / np.sqrt(S) <= sigma_b / np.sqrt(S - 1):
            break
    return _make_schedule(prob, best_mask, total_iters, "FSCD")


# ---------------------------------------------------------------------------
# CD baseline (Fig. 3): plain coordinate descent on 1-flip neighborhoods


def coordinate_descent(prob: Problem, rng: Optional[np.random.Generator] = None,
                       restarts: int = 4, max_inner: int = 400) -> Schedule:
    rng = rng or np.random.default_rng(0)
    V = prob.num_devices
    feas = prob.feasible()
    best_mask, best_obj = np.zeros(V, bool), np.inf
    total_iters = 0
    for _ in range(restarts):
        mask = rng.random(V) < 0.5
        mask &= feas
        while not prob.bw_ok(mask):              # repair random init
            on = np.flatnonzero(mask)
            if len(on) == 0:
                break
            mask[rng.choice(on)] = False
        obj = prob.objective(mask) if mask.any() else np.inf
        for _ in range(max_inner):
            total_iters += 1
            improved = False
            objs = np.full(V, np.inf)
            for v in range(V):
                if not feas[v] and not mask[v]:
                    continue
                cand = mask.copy()
                cand[v] = ~cand[v]
                if cand.any() and prob.bw_ok(cand):
                    objs[v] = prob.objective(cand)
            v = int(np.argmin(objs))
            if objs[v] < obj - 1e-12:
                mask[v] = ~mask[v]
                obj = objs[v]
                improved = True
            if not improved:
                break
        if obj < best_obj:
            best_obj, best_mask = obj, mask.copy()
    return _make_schedule(prob, best_mask, total_iters, "CD")


# ---------------------------------------------------------------------------
# exact solver (test oracle, V <= ~16)


def exhaustive(prob: Problem) -> Schedule:
    V = prob.num_devices
    assert V <= 20, "exhaustive solver is exponential"
    best_mask, best_obj = np.zeros(V, bool), np.inf
    for bits in range(1, 1 << V):
        mask = np.array([(bits >> v) & 1 for v in range(V)], bool)
        if not prob.bw_ok(mask):
            continue
        obj = prob.objective(mask)
        if obj < best_obj:
            best_obj, best_mask = obj, mask
    return _make_schedule(prob, best_mask, 1 << V, "EXH")


# ---------------------------------------------------------------------------
# batched engine entry point


SOLVE_MANY_ALGORITHMS = ("gs", "fscd", "cd")


def solve_many(problems: Sequence[Problem], algorithm: str = "fscd",
               backend: str = "jax", max_inner: int = 200,
               pallas: Optional[bool] = None, obs=None) -> List[Schedule]:
    """Solve a batch of same-shaped Problems.

    ``backend="numpy"`` loops the reference per-problem solvers;
    ``backend="jax"`` runs the batched float64 engine (identical masks,
    one vectorized pass over the whole batch).  ``algorithm="cd"`` has
    no batched implementation and always uses the numpy loop.

    ``pallas`` routes the jax backend's f32 candidate scans through the
    Pallas ``wemd_swap`` / ``wemd_add`` kernels (None = auto: only on a
    TPU backend).  Scheduling decisions still go through the exact-f64
    top-K re-evaluation, so masks stay bitwise-equal to numpy.

    ``obs`` is a ``repro.obs.Obs`` facade: when enabled, the dispatch
    runs under a ``solve_many.<backend>`` span and updates per-backend
    call + iteration counters (None = the process-wide default, which
    is off unless ``repro.obs.enable_default()`` armed it).
    """
    problems = list(problems)
    if algorithm not in SOLVE_MANY_ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}, "
                         f"expected one of {SOLVE_MANY_ALGORITHMS}")
    if not problems:
        return []
    if obs is None:
        from repro.obs import DEFAULT as obs
    if not obs.enabled:
        return _solve_many_impl(problems, algorithm, backend, max_inner,
                                pallas)
    with obs.span(f"solve_many.{backend}", algorithm=algorithm,
                  batch=len(problems)):
        scheds = _solve_many_impl(problems, algorithm, backend,
                                  max_inner, pallas)
    m = obs.metrics
    m.counter(f"sched.solve_many_calls.{backend}").inc()
    m.counter("sched.problems_total").inc(len(problems))
    m.counter("sched.iterations_total").inc(
        sum(s.iterations for s in scheds))
    return scheds


def _solve_many_impl(problems: List[Problem], algorithm: str,
                     backend: str, max_inner: int,
                     pallas: Optional[bool]) -> List[Schedule]:
    if backend == "numpy" or algorithm == "cd":
        fn = {"gs": greedy_scheduling, "fscd": fscd,
              "cd": coordinate_descent}[algorithm]
        return [fn(p) for p in problems]
    if backend != "jax":
        raise ValueError(f"unknown backend {backend!r}")
    from repro.core import scheduling_jax as SJ
    if algorithm == "gs":
        return SJ.solve_many_gs(problems, pallas=pallas)
    return SJ.solve_many_fscd(problems, max_inner=max_inner, pallas=pallas)


# ---------------------------------------------------------------------------
# baselines (Section VI-A)


def _best_effort(order: np.ndarray, prob: Problem) -> np.ndarray:
    """Schedule devices in the given order until bandwidth runs out."""
    feas = prob.feasible()
    mask = np.zeros(prob.num_devices, bool)
    used = 0.0
    for v in order:
        if not feas[v]:
            continue
        if used + prob.min_bw[v] <= prob.total_bw + 1e-9:
            mask[v] = True
            used += prob.min_bw[v]
        else:
            break
    return mask


def best_channel(prob: Problem, channel_gain: np.ndarray) -> Schedule:
    order = np.argsort(-np.asarray(channel_gain))
    return _make_schedule(prob, _best_effort(order, prob), 1, "BC")


def best_norm(prob: Problem, grad_norms: np.ndarray) -> Schedule:
    order = np.argsort(-np.asarray(grad_norms))
    return _make_schedule(prob, _best_effort(order, prob), 1, "BN")


def power_of_choice(prob: Problem, losses: np.ndarray, num_sampled: int,
                    rng: Optional[np.random.Generator] = None) -> Schedule:
    rng = rng or np.random.default_rng(0)
    V = prob.num_devices
    sampled = rng.choice(V, size=min(num_sampled, V), replace=False)
    order = sampled[np.argsort(-np.asarray(losses)[sampled])]
    return _make_schedule(prob, _best_effort(order, prob), 1, "POC")


def random_schedule(prob: Problem,
                    rng: Optional[np.random.Generator] = None) -> Schedule:
    rng = rng or np.random.default_rng(0)
    order = rng.permutation(prob.num_devices)
    return _make_schedule(prob, _best_effort(order, prob), 1, "RAND")


# --- Fed-CBS [40]: QCID-minimizing sequential sampling with UCB bonus ----


def qcid(p_dev: np.ndarray, mask: np.ndarray, global_dist: np.ndarray) -> float:
    """Quadratic class-imbalance degree of the group distribution."""
    g = WE.group_distribution(p_dev, mask)
    return float(((g - global_dist) ** 2).sum())


def fed_cbs(prob: Problem, plays: np.ndarray, round_idx: int,
            ucb_beta: float = 0.05,
            rng: Optional[np.random.Generator] = None) -> Schedule:
    """Sequentially add the device minimizing group QCID minus a
    combinatorial-UCB exploration bonus, best-effort within bandwidth."""
    rng = rng or np.random.default_rng(0)
    V = prob.num_devices
    feas = prob.feasible()
    mask = np.zeros(V, bool)
    used = 0.0
    bonus = ucb_beta * np.sqrt(
        np.log(max(round_idx, 1) + 1.0) / np.maximum(plays, 1.0))
    while True:
        cand = feas & ~mask & (prob.min_bw <= prob.total_bw - used + 1e-9)
        if not cand.any():
            break
        scores = np.full(V, np.inf)
        for v in np.flatnonzero(cand):
            m2 = mask.copy()
            m2[v] = True
            scores[v] = qcid(prob.p_dev, m2, prob.global_dist) - bonus[v]
        v = int(np.argmin(scores))
        cur = qcid(prob.p_dev, mask, prob.global_dist) if mask.any() else np.inf
        if scores[v] >= cur and mask.sum() >= 1:
            break
        mask[v] = True
        used += prob.min_bw[v]
    return _make_schedule(prob, mask, 1, "FCBS")
