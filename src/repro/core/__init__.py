"""FedCGD core: the paper's contribution (WEMD, multi-level CGD,
bandwidth-feasible scheduling)."""
from repro.core.scheduling import (  # noqa: F401
    Problem,
    Schedule,
    best_channel,
    best_norm,
    coordinate_descent,
    exhaustive,
    fed_cbs,
    fscd,
    greedy_scheduling,
    power_of_choice,
    random_schedule,
    solve_many,
)
from repro.core.wemd import p1_objective, wemd_of_set  # noqa: F401
from repro.core.bandwidth import min_bandwidth, uplink_rate  # noqa: F401
