"""Weighted Earth-Mover Distance (paper Eq. 8).

For a classification problem, device-level collective gradient divergence
is bounded by

    Delta <= sum_c | sum_{v in Pi} p_{v,c} / |Pi|  -  p_c | * G_c

— the WEMD between the *group* label distribution of the scheduled set and
the global label distribution, weighted by per-class gradient norms G_c.

All functions are numpy (host-side scheduling math, exactly like the
paper's simulation); the estimation of G_c / sigma from gradients is JAX
and lives in core/estimation.py.
"""
from __future__ import annotations

import numpy as np


def normalize_rows(counts: np.ndarray) -> np.ndarray:
    counts = np.asarray(counts, dtype=np.float64)
    s = counts.sum(axis=-1, keepdims=True)
    return counts / np.maximum(s, 1e-12)


def wemd(group_dist: np.ndarray, global_dist: np.ndarray,
         class_weights: np.ndarray) -> float:
    """WEMD between a group distribution and the global distribution."""
    return float(np.abs(group_dist - global_dist) @ class_weights)


def group_distribution(p_dev: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """p_dev [V,C] per-device label distributions; mask [V] bool/0-1.
    Equal aggregation weights (paper Sec. V-A assumes equal |D_v|)."""
    mask = np.asarray(mask, dtype=np.float64)
    s = mask.sum()
    if s == 0:
        return np.zeros(p_dev.shape[1])
    return mask @ p_dev / s


def wemd_of_set(p_dev: np.ndarray, mask: np.ndarray, global_dist: np.ndarray,
                class_weights: np.ndarray) -> float:
    """W(Pi) in Algorithm 1/2. Empty set convention: W = sum_c p_c G_c
    (max distance)."""
    mask = np.asarray(mask, dtype=np.float64)
    if mask.sum() == 0:
        return float(global_dist @ class_weights)
    return wemd(group_distribution(p_dev, mask), global_dist, class_weights)


def wemd_add_candidates(p_sum: np.ndarray, size: int, p_dev: np.ndarray,
                        global_dist: np.ndarray,
                        class_weights: np.ndarray) -> np.ndarray:
    """Vectorized W(Pi ∪ {v}) for all v given the current group sum.

    p_sum [C] = sum of distributions of the current set of ``size``
    devices.  Returns [V] WEMD values.  O(V*C)."""
    new = (p_sum[None, :] + p_dev) / (size + 1)
    return np.abs(new - global_dist[None, :]) @ class_weights


def wemd_swap_candidates(p_sum: np.ndarray, size: int, p_dev: np.ndarray,
                         in_idx: np.ndarray, out_idx: np.ndarray,
                         global_dist: np.ndarray,
                         class_weights: np.ndarray) -> np.ndarray:
    """Vectorized W(Pi \\ {i} ∪ {j}) over all (i in set, j out of set).

    Returns [len(in_idx), len(out_idx)].  O(|in|*|out|*C) — the FSCD
    inner loop."""
    base = p_sum[None, None, :] - p_dev[in_idx][:, None, :] \
        + p_dev[out_idx][None, :, :]
    dist = base / size
    return np.abs(dist - global_dist[None, None, :]) @ class_weights


def sampling_variance(sigma: float, num_scheduled: int, batch_size: int) -> float:
    """sigma / sqrt(|Pi| * b) — Lemma 2's sample-level CGD bound."""
    if num_scheduled <= 0:
        return np.inf
    return sigma / np.sqrt(num_scheduled * batch_size)


def p1_objective(mask: np.ndarray, p_dev: np.ndarray, global_dist: np.ndarray,
                 class_weights: np.ndarray, sigma: float,
                 batch_size: int) -> float:
    """The P1 objective: sampling variance + WEMD."""
    s = int(np.asarray(mask).sum())
    return sampling_variance(sigma, s, batch_size) + wemd_of_set(
        p_dev, mask, global_dist, class_weights)
