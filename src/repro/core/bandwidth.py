"""FDMA minimum-bandwidth allocation (paper Eq. 9).

A scheduled device must upload D_w bits within deadline d_cm at rate
    r = B log2(1 + S*H / (B*N0)),
so the minimal feasible bandwidth solves r(B) * d_cm = D_w.  Substituting
u = S*H/(N0*B) gives ln(1+u)/u = Gamma with
    Gamma = N0 * D_w * ln2 / (d_cm * S * H),
whose non-trivial root is u = -W_{-1}(-Gamma e^{-Gamma})/Gamma - 1 for
Gamma < 1; Gamma >= 1 means the required rate exceeds the channel's
capacity limit S*H/(N0 ln2) — infeasible even with infinite bandwidth
(the paper's "minus B*" case, excluded by the last constraint of P1).
"""
from __future__ import annotations

import numpy as np
from scipy.special import lambertw


def min_bandwidth(data_bits: float, deadline_s: float, tx_power_gain: np.ndarray,
                  noise_psd: float) -> np.ndarray:
    """Vectorized Eq. 9.

    tx_power_gain = S * H_v (received signal power, W).
    Returns B_v* in Hz; -1.0 marks infeasible devices."""
    sh = np.asarray(tx_power_gain, dtype=np.float64)
    gamma = noise_psd * data_bits * np.log(2.0) / (deadline_s * sh)
    feasible = gamma < 1.0
    g = np.where(feasible, gamma, 0.5)           # safe placeholder
    w = lambertw(-g * np.exp(-g), k=-1).real     # W_{-1} branch
    bstar = -data_bits * np.log(2.0) / (deadline_s * (w + g))
    return np.where(feasible, bstar, -1.0)


def min_bandwidth_bisect(data_bits: float, deadline_s: float, sh: float,
                         noise_psd: float, tol: float = 1e-9) -> float:
    """Reference root-finder for tests (no Lambert-W)."""
    cap = sh / (noise_psd * np.log(2.0))         # B -> inf rate limit
    need = data_bits / deadline_s
    if need >= cap:
        return -1.0

    def rate(b):
        return b * np.log2(1.0 + sh / (b * noise_psd))

    lo, hi = 1e-6, 1.0
    while rate(hi) < need:
        hi *= 2.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if rate(mid) < need:
            lo = mid
        else:
            hi = mid
        if hi - lo < tol * hi:
            break
    return 0.5 * (lo + hi)


def deadline_met(bandwidth_hz, data_bits: float, deadline_s: float,
                 tx_power_gain, noise_psd: float,
                 slack: float = 0.0) -> np.ndarray:
    """Eq. 9 feasibility at a *fixed* allocation (vectorized).

    True where a device granted ``bandwidth_hz`` can push ``data_bits``
    within ``(1 + slack) * deadline_s`` at the given received power —
    the upload-time check of the fault layer, where the gain may have
    shadow-faded since B* was allocated.  Non-positive bandwidth (the
    infeasible marker) is never met.  A relative 1e-9 tolerance keeps
    Eq. 9's equality allocation (rate(B*) * d_cm == D_w up to Lambert-W
    rounding) on the feasible side."""
    b = np.asarray(bandwidth_hz, dtype=np.float64)
    ok = b > 0
    rate = uplink_rate(np.where(ok, b, 1.0), tx_power_gain, noise_psd)
    return ok & (rate * deadline_s * (1.0 + slack)
                 >= data_bits * (1.0 - 1e-9))


def uplink_rate(bandwidth_hz, tx_power_gain, noise_psd):
    """Shannon FDMA rate r = B log2(1 + S*H/(B*N0)) (vectorized)."""
    b = np.asarray(bandwidth_hz, dtype=np.float64)
    return b * np.log2(1.0 + np.asarray(tx_power_gain) / (b * noise_psd))
