"""On-line parameter estimation for P1 (paper Section V-C, Eq. 10-12).

sigma (stochastic-gradient std): estimated per device from the first batch
as the rms deviation of per-sample gradients from the batch gradient
(Eq. 10), then aggregated as sqrt(sum_v alpha_v sigma_v^2) (Eq. 11).

G (class-gradient norm scale): estimated from model deltas after local
update, G = max_v ||grad_v - grad_global|| / ||p_v - p||_1 (Eq. 12); when
every device holds a single class, per-class G_c is available (the
FedCGD-FSCD-Gc variant).

Per-sample gradients are the compute hot-spot here: naively vmapping
grad() materializes B copies of the model gradient.  For softmax-CE
classifiers the last-layer norm admits the decomposition
||g_i||^2 = ||p_i - y_i||^2 * ||h_i||^2 (+1 for the bias), which
``repro.kernels.persample_gradnorm`` fuses on TPU; `sigma_hat_lastlayer`
uses that structure.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


def tree_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_weighted_sum(trees, weights):
    out = jax.tree.map(lambda x: x * weights[0], trees[0])
    for t, w in zip(trees[1:], weights[1:]):
        out = jax.tree.map(lambda x, y: x + y * w, out, t)
    return out


# ---------------------------------------------------------------------------
# Eq. 10-11: sigma estimation


def sigma_hat_exact(loss_per_sample: Callable, params, batch) -> jax.Array:
    """Eq. 10 by brute force: vmapped per-sample grads.

    loss_per_sample(params, example) -> scalar; batch is a pytree whose
    leaves have a leading batch dim."""
    grads = jax.vmap(lambda ex: jax.grad(loss_per_sample)(params, ex))(batch)
    mean_grad = jax.tree.map(lambda g: g.mean(0), grads)
    dev = jax.tree.map(lambda g, m: g - m[None], grads, mean_grad)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)), axis=tuple(
        range(1, x.ndim))) for x in jax.tree.leaves(dev))
    return jnp.sqrt(sq.mean())


def sigma_hat_lastlayer(features: jax.Array, logits: jax.Array,
                        labels: jax.Array, use_kernel: bool = False) -> jax.Array:
    """Eq. 10 restricted to the classifier head W in R^{d x C}:
    per-sample grad g_i = h_i (p_i - y_i)^T, so
    ||g_i - gbar||^2 is computed without materializing [B, d, C].

    This is the quantity FedCGD ships to the server each round; the full-
    model sigma is proportional for well-conditioned nets (validated in
    tests against sigma_hat_exact)."""
    if use_kernel:
        from repro.kernels import ops
        return ops.persample_gradnorm_sigma(features, logits, labels)
    h = features.astype(jnp.float32)                       # [B, d]
    p = jax.nn.softmax(logits.astype(jnp.float32), -1)     # [B, C]
    e = p - jax.nn.one_hot(labels, logits.shape[-1])       # [B, C]
    gbar_flat = (h.T @ e) / h.shape[0]                     # [d, C]
    # ||g_i||^2 = ||h_i||^2 ||e_i||^2 ; <g_i, gbar> = h_i^T gbar e_i
    gi_sq = (h * h).sum(-1) * (e * e).sum(-1)              # [B]
    cross = jnp.einsum("bd,dc,bc->b", h, gbar_flat, e)
    gbar_sq = jnp.sum(gbar_flat * gbar_flat)
    dev_sq = gi_sq - 2.0 * cross + gbar_sq
    return jnp.sqrt(jnp.maximum(dev_sq.mean(), 0.0))


def sigma_hat_global(sigma_v: np.ndarray, alpha: np.ndarray) -> float:
    """Eq. 11: sqrt(sum_v alpha_v sigma_v^2)."""
    return float(np.sqrt(np.sum(np.asarray(alpha) *
                                np.square(np.asarray(sigma_v)))))


# ---------------------------------------------------------------------------
# Eq. 12: G estimation from model deltas


def device_grad_estimate(w_new, w_old, tau: int, eta: float):
    """nabla f_v ≈ (w_old - w_new)/(tau*eta)  (descent direction)."""
    return jax.tree.map(lambda a, b: (b - a) / (tau * eta), w_new, w_old)


@jax.jit
def _centered_grad_norms(grads_stacked, alphas):
    """[U] norms ||grad_v - sum_u alpha_u grad_u|| from a stacked [U, ...]
    gradient pytree — the Eq. 12 numerators in one fused program."""
    def center(g):
        a = alphas.reshape((-1,) + (1,) * (g.ndim - 1)).astype(g.dtype)
        return g - (g * a).sum(0)[None]
    return jax.vmap(tree_norm)(jax.tree.map(center, grads_stacked))


def _grad_deviation_norms(device_grads, alphas) -> np.ndarray:
    """Accepts a list of per-device pytrees *or* one pytree with a
    leading [U] device axis; returns the [U] deviation norms with a
    single device dispatch + host pull."""
    if isinstance(device_grads, (list, tuple)):
        device_grads = jax.tree.map(lambda *xs: jnp.stack(xs),
                                    *device_grads)
    alphas = jnp.asarray(np.asarray(alphas, dtype=np.float64),
                         dtype=jnp.float32)
    return np.asarray(_centered_grad_norms(device_grads, alphas))


def g_hat(device_grads, alphas, p_dev: np.ndarray,
          global_dist: np.ndarray,
          norms: Optional[np.ndarray] = None) -> float:
    """Eq. 12: max_v ||grad_v - grad_global|| / ||p_v - p||_1.

    ``device_grads`` is a list of per-device pytrees or a stacked pytree
    with a leading [U] device axis (the trainer's fused path).  When the
    [U] deviation norms were already computed device-side (the fused
    finalize core), pass them as ``norms`` — no device round-trip is
    made and ``device_grads``/``alphas`` may be None."""
    if norms is None:
        norms = _grad_deviation_norms(device_grads, alphas)
    l1 = np.abs(np.asarray(p_dev) - np.asarray(global_dist)).sum(axis=1)
    valid = l1 >= 1e-9
    if not valid.any():
        return 0.0
    return float(np.max(norms[valid] / l1[valid]))


def g_hat_per_class(device_grads, alphas, device_class: np.ndarray,
                    p_dev: np.ndarray, global_dist: np.ndarray,
                    num_classes: int,
                    norms: Optional[np.ndarray] = None) -> np.ndarray:
    """Per-class G_c when each device holds a single class (the paper's
    FedCGD-FSCD-Gc variant): G_c = max_{v in Pi_c} ||grad_v - grad|| /
    ||p_v - p||_1.  ``norms`` as in ``g_hat``."""
    if norms is None:
        norms = _grad_deviation_norms(device_grads, alphas)
    l1 = np.abs(np.asarray(p_dev) - np.asarray(global_dist)).sum(axis=1)
    G = np.zeros(num_classes)
    for v in range(len(norms)):
        if l1[v] < 1e-9:
            continue
        c = int(device_class[v])
        G[c] = max(G[c], float(norms[v]) / l1[v])
    # classes never seen this round fall back to the max (conservative)
    fallback = G.max() if G.max() > 0 else 1.0
    return np.where(G > 0, G, fallback)
