"""Non-IID client partitioners (paper Section VI-A).

(1) sort_and_partition(l, r): sort by label, split into shards, give each
    device l shards; smaller l = more heterogeneity.  The *total* dataset
    may itself be imbalanced: the second half of the classes is
    oversampled by the imbalance ratio r = n2/n1 (r in {1,3,9} in Fig. 5).
(2) dirichlet(alpha): each device's label distribution ~ Dir(alpha * p).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def apply_imbalance(labels: np.ndarray, ratio: float,
                    rng: np.random.Generator) -> np.ndarray:
    """Subsample indices so second-half classes outnumber first-half ones
    by `ratio` (returns indices into the dataset)."""
    classes = np.unique(labels)
    half = len(classes) // 2
    idx = []
    for c in classes:
        ci = np.flatnonzero(labels == c)
        rng.shuffle(ci)
        if ratio >= 1:
            keep = len(ci) if c >= classes[half] else int(len(ci) / ratio)
        else:
            keep = len(ci) if c < classes[half] else int(len(ci) * ratio)
        idx.append(ci[:keep])
    out = np.concatenate(idx)
    rng.shuffle(out)
    return out


def sort_and_partition(labels: np.ndarray, num_devices: int,
                       shards_per_device: int,
                       rng: np.random.Generator) -> List[np.ndarray]:
    """Each device receives `shards_per_device` contiguous label-sorted
    shards. Returns per-device index arrays."""
    order = np.argsort(labels, kind="stable")
    num_shards = num_devices * shards_per_device
    shards = np.array_split(order, num_shards)
    shard_ids = rng.permutation(num_shards)
    out = []
    for v in range(num_devices):
        ids = shard_ids[v * shards_per_device:(v + 1) * shards_per_device]
        out.append(np.concatenate([shards[i] for i in ids]))
    return out


def dirichlet_partition(labels: np.ndarray, num_devices: int, alpha: float,
                        rng: np.random.Generator,
                        samples_per_device: int = 0) -> List[np.ndarray]:
    """Device label distribution ~ Dir(alpha * p); equal device sizes
    (paper: each device holds the same number of samples)."""
    classes = np.unique(labels)
    p_global = np.array([(labels == c).mean() for c in classes])
    if samples_per_device == 0:
        samples_per_device = len(labels) // num_devices
    pools = {c: list(rng.permutation(np.flatnonzero(labels == c)))
             for c in classes}
    out = []
    for _ in range(num_devices):
        pv = rng.dirichlet(alpha * p_global * len(classes))
        counts = rng.multinomial(samples_per_device, pv)
        take = []
        for c, n in zip(classes, counts):
            pool = pools[c]
            got = [pool.pop() for _ in range(min(n, len(pool)))]
            take.extend(got)
        # top up from whatever is left if some pools ran dry
        short = samples_per_device - len(take)
        if short > 0:
            rest = [i for pool in pools.values() for i in pool]
            rng.shuffle(rest)
            grabbed = rest[:short]
            take.extend(grabbed)
            grabbed_set = set(grabbed)
            for c in classes:
                pools[c] = [i for i in pools[c] if i not in grabbed_set]
        out.append(np.array(take, dtype=np.int64))
    return out


def label_distributions(labels: np.ndarray, device_indices: List[np.ndarray],
                        num_classes: int) -> np.ndarray:
    """[V, C] empirical label distribution of each device."""
    out = np.zeros((len(device_indices), num_classes))
    for v, idx in enumerate(device_indices):
        if len(idx):
            out[v] = np.bincount(labels[idx], minlength=num_classes) / len(idx)
    return out


def global_distribution(labels: np.ndarray, device_indices: List[np.ndarray],
                        num_classes: int) -> np.ndarray:
    """Label distribution of the union of participating devices' data."""
    all_idx = np.concatenate([i for i in device_indices if len(i)])
    return np.bincount(labels[all_idx], minlength=num_classes) / len(all_idx)
