"""Offline-safe datasets.

No network access in this container, so CIFAR-10/100 are replaced by
*synthetic class-structured image datasets*: each class has a random but
fixed spatial template; samples are template + per-sample noise + random
shifts.  A linear probe cannot solve it at high noise, a small CNN can —
exactly the regime the paper's scheduling effects need (label
distributions drive gradients).  Token datasets for the LM architectures
are class-structured Markov streams so that "label histograms" (token
superclass histograms, DESIGN.md §4) are meaningful.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class ArrayDataset:
    inputs: np.ndarray     # images [N,H,W,C] f32  or tokens [N,S] i32
    labels: np.ndarray     # [N] int
    num_classes: int

    def __len__(self):
        return len(self.labels)


def synthetic_image_dataset(num_classes: int = 10, num_per_class: int = 500,
                            image_size: int = 32, channels: int = 3,
                            noise: float = 0.6, seed: int = 0,
                            ) -> ArrayDataset:
    """CIFAR-like synthetic classification data."""
    rng = np.random.default_rng(seed)
    templates = rng.normal(0, 1, (num_classes, image_size, image_size,
                                  channels)).astype(np.float32)
    # smooth the templates a little so shifts matter
    templates = (templates + np.roll(templates, 1, 1)
                 + np.roll(templates, 1, 2)) / 3.0
    xs, ys = [], []
    for c in range(num_classes):
        shift = rng.integers(-3, 4, size=(num_per_class, 2))
        for s in range(num_per_class):
            img = np.roll(templates[c], tuple(shift[s]), axis=(0, 1))
            xs.append(img + rng.normal(0, noise, img.shape))
            ys.append(c)
    xs = np.stack(xs).astype(np.float32)
    ys = np.array(ys, dtype=np.int32)
    perm = rng.permutation(len(ys))
    return ArrayDataset(xs[perm], ys[perm], num_classes)


def synthetic_token_dataset(vocab_size: int, seq_len: int,
                            num_classes: int = 16, num_per_class: int = 64,
                            seed: int = 0) -> ArrayDataset:
    """Class-structured token streams: class c biases a distinct slice of
    the vocabulary (so token-superclass histograms separate classes)."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    bucket = max(vocab_size // num_classes, 1)
    for c in range(num_classes):
        lo = c * bucket
        for _ in range(num_per_class):
            base = rng.integers(0, vocab_size, size=seq_len)
            biased = rng.integers(lo, min(lo + bucket, vocab_size),
                                  size=seq_len)
            pick = rng.random(seq_len) < 0.7
            xs.append(np.where(pick, biased, base))
            ys.append(c)
    xs = np.stack(xs).astype(np.int32)
    ys = np.array(ys, dtype=np.int32)
    perm = rng.permutation(len(ys))
    return ArrayDataset(xs[perm], ys[perm], num_classes)


def train_test_split(ds: ArrayDataset, test_frac: float = 0.2,
                     seed: int = 0) -> Tuple[ArrayDataset, ArrayDataset]:
    rng = np.random.default_rng(seed)
    n = len(ds)
    perm = rng.permutation(n)
    nt = int(n * test_frac)
    te, tr = perm[:nt], perm[nt:]
    return (ArrayDataset(ds.inputs[tr], ds.labels[tr], ds.num_classes),
            ArrayDataset(ds.inputs[te], ds.labels[te], ds.num_classes))


def batch_iterator(ds: ArrayDataset, indices: np.ndarray, batch_size: int,
                   rng: np.random.Generator):
    """Endless shuffled batches over a device's index set."""
    idx = np.array(indices)
    while True:
        rng.shuffle(idx)
        for i in range(0, len(idx) - batch_size + 1, batch_size):
            take = idx[i:i + batch_size]
            yield ds.inputs[take], ds.labels[take]


def sample_batch(ds: ArrayDataset, indices: np.ndarray, batch_size: int,
                 rng: np.random.Generator):
    take = rng.choice(indices, size=min(batch_size, len(indices)),
                      replace=len(indices) < batch_size)
    return ds.inputs[take], ds.labels[take]
