from repro.data.datasets import (  # noqa: F401
    ArrayDataset,
    batch_iterator,
    sample_batch,
    synthetic_image_dataset,
    synthetic_token_dataset,
    train_test_split,
)
from repro.data.partition import (  # noqa: F401
    apply_imbalance,
    dirichlet_partition,
    global_distribution,
    label_distributions,
    sort_and_partition,
)
