"""Flat-npz pytree checkpointing.

Sharded arrays are gathered to host before saving (fine for the FL-scale
models trained in this container; the big dry-run configs are never
materialized, so they are never checkpointed).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(path: str, tree: Any, metadata: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f, indent=2)


def load_pytree(path: str, like: Any) -> Any:
    """Restore into the structure of `like`."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pathspec, leaf in flat_like:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in pathspec)
        arr = data[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
