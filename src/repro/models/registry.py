"""Model registry: a uniform functional handle over every architecture."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHITECTURES, CNN_MODELS, get_config
from repro.configs.base import ModelConfig
from repro.configs.paper_cnn import CNNConfig
from repro.models import cnn as C
from repro.models import transformer as T
from repro.models.layers import softmax_cross_entropy


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable
    loss_fn: Callable          # (params, batch, rng=None, ctx=None) -> (loss, metrics)
    forward: Callable
    init_cache: Optional[Callable] = None
    serve_step: Optional[Callable] = None

    @property
    def name(self):
        return self.cfg.name


def _lm_model(cfg: ModelConfig) -> Model:
    def loss(params, batch, rng=None, ctx=None):
        return T.loss_fn(params, cfg, batch, ctx)

    return Model(
        cfg=cfg,
        init=lambda key: T.init(key, cfg),
        loss_fn=loss,
        forward=lambda params, batch, ctx=None, **kw: T.forward(
            params, cfg, batch, ctx, **kw),
        init_cache=lambda batch, cache_len: T.init_cache(cfg, batch, cache_len),
        serve_step=lambda params, cache, batch, ctx=None: T.serve_step(
            params, cfg, cache, batch, ctx),
    )


def _cnn_model(cfg: CNNConfig) -> Model:
    def loss(params, batch, rng=None, ctx=None):
        logits = C.cnn_forward(params, cfg, batch["images"], rng)
        ce = softmax_cross_entropy(logits, batch["labels"])
        acc = (logits.argmax(-1) == batch["labels"]).mean()
        return ce, {"ce_loss": ce, "accuracy": acc}

    return Model(
        cfg=cfg,
        init=lambda key: C.init_cnn(key, cfg),
        loss_fn=loss,
        forward=lambda params, batch, ctx=None, rng=None: C.cnn_forward(
            params, cfg, batch["images"], rng),
    )


def build_model(arch_or_cfg) -> Model:
    """arch name, ModelConfig, or CNNConfig -> Model."""
    if isinstance(arch_or_cfg, CNNConfig):
        return _cnn_model(arch_or_cfg)
    if isinstance(arch_or_cfg, ModelConfig):
        return _lm_model(arch_or_cfg)
    name = arch_or_cfg
    if name in CNN_MODELS:
        return _cnn_model(CNN_MODELS[name])
    return _lm_model(get_config(name))
