"""Unified decoder for every assigned architecture family.

Layer heterogeneity (gemma3 5:1 local:global, Griffin 2:1 recurrent:attn,
Llama-Vision 4:1 self:cross) is handled by scanning over *pattern periods*:
parameters of one period are initialized per-layer-kind and stacked across
the ``num_full_periods`` repetitions, so the HLO contains each layer kind
once regardless of depth — essential for the 512-device AOT dry-run's
compile time.  Remainder layers (62 = 10x6 + 2) are applied unrolled.

Public API (functional):
    init(key, cfg)                                  -> params
    forward(params, cfg, batch, ctx, collect_cache) -> (logits, aux, cache)
    loss_fn(params, cfg, batch, ctx)                -> (loss, metrics)
    init_cache(cfg, batch_size, cache_len)          -> cache pytree
    serve_step(params, cfg, cache, batch, ctx)      -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (CROSS_ATTN, GLOBAL_ATTN, LOCAL_ATTN,
                                RECURRENT, RWKV, ModelConfig)
from repro.sharding import ShardingCtx, constrain
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import rwkv6 as W

ATTN_KINDS = (GLOBAL_ATTN, LOCAL_ATTN, CROSS_ATTN)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init


def init_layer(key, cfg: ModelConfig, kind: str):
    dt = _pdtype(cfg)
    d = cfg.d_model
    k1, k2 = jax.random.split(key)
    if kind == RWKV:
        return {
            "ln1": W.layer_norm_init(d, dt),
            "tm": W.time_mix_init(k1, cfg, dt),
            "ln2": W.layer_norm_init(d, dt),
            "cm": W.channel_mix_init(k2, cfg, dt),
        }
    p = {"norm1": L.rms_norm_init(d, dt), "norm2": L.rms_norm_init(d, dt)}
    if kind == RECURRENT:
        p["rec"] = R.recurrent_block_init(k1, cfg, dt)
        p["ffn"] = L.swiglu_init(k2, d, cfg.d_ff, dt)
    else:
        p["attn"] = L.attention_params_init(k1, cfg, dt,
                                            cross=(kind == CROSS_ATTN))
        if cfg.num_experts:
            p["ffn"] = M.moe_params_init(k2, cfg, dt)
        else:
            p["ffn"] = L.swiglu_init(k2, d, cfg.d_ff, dt)
    return p


def init_period(key, cfg: ModelConfig):
    keys = jax.random.split(key, cfg.pattern_period)
    return {f"layer{i}": init_layer(keys[i], cfg, kind)
            for i, kind in enumerate(cfg.block_pattern)}


def init(key, cfg: ModelConfig):
    dt = _pdtype(cfg)
    keys = jax.random.split(key, 6 + cfg.num_remainder_layers)
    params = {}
    if cfg.family == "audio":
        params["embed_proj"] = L.dense_init(
            keys[0], (cfg.encoder_dim, cfg.d_model), dtype=dt)
    else:
        params["embed"] = L.embed_init(
            keys[0], (cfg.vocab_size, cfg.d_model), dtype=dt)
    if cfg.family == "vlm" and cfg.encoder_dim != cfg.d_model:
        params["enc_proj"] = L.dense_init(
            keys[1], (cfg.encoder_dim, cfg.d_model), dtype=dt)

    nper = cfg.num_full_periods
    if nper:
        pkeys = jax.random.split(keys[2], nper)
        params["blocks"] = jax.vmap(
            lambda k: init_period(k, cfg))(pkeys)
    for i in range(cfg.num_remainder_layers):
        params[f"rem{i}"] = init_layer(keys[6 + i], cfg,
                                       cfg.block_pattern[i])
    params["final_norm"] = (W.layer_norm_init(cfg.d_model, dt)
                            if RWKV in cfg.block_pattern
                            else L.rms_norm_init(cfg.d_model, dt))
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(
            keys[3], (cfg.d_model, cfg.vocab_size), dtype=dt)
    return params


# ---------------------------------------------------------------------------
# per-layer state (decode cache / recurrent state)


def init_layer_state(cfg: ModelConfig, kind: str, batch: int, cache_len: int):
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    dt = _dtype(cfg)
    if kind == GLOBAL_ATTN:
        n = cache_len
    elif kind == LOCAL_ATTN:
        n = min(cfg.window_size, cache_len)
    elif kind == CROSS_ATTN:
        return {"k": jnp.zeros((batch, cfg.num_encoder_tokens, KV, hd), dt),
                "v": jnp.zeros((batch, cfg.num_encoder_tokens, KV, hd), dt)}
    elif kind == RECURRENT:
        return R.init_recurrent_state(cfg, batch)
    elif kind == RWKV:
        H = cfg.num_heads
        rhd = cfg.rwkv_head_dim
        return {"shift1": jnp.zeros((batch, cfg.d_model), dt),
                "shift2": jnp.zeros((batch, cfg.d_model), dt),
                "wkv": jnp.zeros((batch, H, rhd, rhd), jnp.float32)}
    else:
        raise ValueError(kind)
    return {"k": jnp.zeros((batch, n, KV, hd), dt),
            "v": jnp.zeros((batch, n, KV, hd), dt),
            "slot_pos": jnp.full((n,), -1, jnp.int32),
            "next_slot": jnp.zeros((), jnp.int32)}


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Decode cache for the whole model (stacked over periods)."""
    def period_state():
        return {f"layer{i}": init_layer_state(cfg, kind, batch, cache_len)
                for i, kind in enumerate(cfg.block_pattern)}
    cache = {}
    nper = cfg.num_full_periods
    if nper:
        one = period_state()
        cache["blocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (nper,) + x.shape).copy(), one)
    for i in range(cfg.num_remainder_layers):
        cache[f"rem{i}"] = init_layer_state(cfg, cfg.block_pattern[i],
                                            batch, cache_len)
    cache["pos"] = jnp.zeros((), jnp.int32)
    return cache


# ---------------------------------------------------------------------------
# layer application


def apply_layer(p, cfg: ModelConfig, kind: str, x, *, enc=None, q_pos=None,
                ctx=None, state=None, decode=False, collect_cache=False,
                cache_len: int = 0):
    """Returns (x, aux_loss, new_state).

    The residual stream is kept sequence-sharded over the tensor-parallel
    axis between layers (Megatron-LM sequence parallelism): the scan over
    periods then stores only a 1/TP-degree slice per layer for backward —
    the difference between fitting and not fitting 4k-seq training in HBM
    (DESIGN.md §3)."""
    aux = jnp.zeros((), jnp.float32)
    new_state = state
    x = constrain(x, ctx, "batch", "sp", None)

    if kind == RWKV:
        h = W.layer_norm(p["ln1"], x)
        if decode:
            y, s1, wkv = W.time_mix_step(p["tm"], cfg, h, state["shift1"],
                                         state["wkv"])
        else:
            B = x.shape[0]
            s1_0 = jnp.zeros((B, cfg.d_model), x.dtype) if state is None \
                else state["shift1"]
            wkv_0 = jnp.zeros((B, cfg.num_heads, cfg.rwkv_head_dim,
                               cfg.rwkv_head_dim), jnp.float32) \
                if state is None else state["wkv"]
            y, s1, wkv = W.time_mix(p["tm"], cfg, h, s1_0, wkv_0, ctx)
        x = x + y
        h = W.layer_norm(p["ln2"], x)
        s2_0 = (jnp.zeros((x.shape[0], cfg.d_model), x.dtype)
                if (state is None and not decode) else
                (state["shift2"] if state is not None else None))
        y, s2 = W.channel_mix(p["cm"], h, s2_0, ctx)
        x = x + y
        if collect_cache or decode:
            new_state = {"shift1": s1.astype(_dtype(cfg)),
                         "shift2": s2.astype(_dtype(cfg)), "wkv": wkv}
        return x, aux, new_state

    if kind == RECURRENT:
        h = L.rms_norm(p["norm1"], x)
        s0 = R.init_recurrent_state(cfg, x.shape[0]) if state is None else state
        y, s = R.recurrent_block(p["rec"], cfg, h, s0, ctx, decode=decode)
        x = x + y
        x = x + L.swiglu(p["ffn"], L.rms_norm(p["norm2"], x), ctx,
                         act=jax.nn.gelu)
        return x, aux, (s if (collect_cache or decode) else state)

    # attention kinds -------------------------------------------------
    h = L.rms_norm(p["norm1"], x)
    window = cfg.window_size if kind == LOCAL_ATTN else 0
    theta = (cfg.rope_theta_local if kind == LOCAL_ATTN else cfg.rope_theta)
    if kind == CROSS_ATTN:
        if decode:
            y, _, _ = L.multihead_attention(
                p["attn"], cfg, h, q_pos=None, causal=False, ctx=ctx,
                cache=state, cache_fixed_kv=True)
        else:
            y, _, kv = L.multihead_attention(
                p["attn"], cfg, h, kv_x=enc, q_pos=None, causal=False,
                ctx=ctx)
            if collect_cache:
                new_state = {"k": kv[0], "v": kv[1]}
    elif decode:
        y, new_state, _ = L.multihead_attention(
            p["attn"], cfg, h, q_pos=q_pos, causal=True, window=window,
            rope_theta=theta, ctx=ctx, cache=state)
    else:
        y, _, kv = L.multihead_attention(
            p["attn"], cfg, h, q_pos=q_pos, causal=True, window=window,
            rope_theta=theta, ctx=ctx)
        if collect_cache:
            new_state = _prefill_cache(cfg, kind, kv, q_pos, cache_len)
    x = x + y

    h = L.rms_norm(p["norm2"], x)
    if cfg.num_experts and kind != CROSS_ATTN:
        y, aux = M.moe_ffn(p["ffn"], cfg, h, ctx)
    elif cfg.num_experts:
        y, aux = M.moe_ffn(p["ffn"], cfg, h, ctx)
    else:
        y = L.swiglu(p["ffn"], h, ctx)
    x = x + y
    return x, aux, new_state


def _prefill_cache(cfg: ModelConfig, kind: str, kv, q_pos, cache_len: int):
    """Pack prefill-computed KV into a decode cache buffer."""
    k, v = kv
    B, S = k.shape[0], k.shape[1]
    if kind == LOCAL_ATTN:
        n = min(cfg.window_size, cache_len)
    else:
        n = cache_len
    pos = jnp.broadcast_to(q_pos, (B, S))[0] if q_pos is not None \
        else jnp.arange(S)
    if S >= n:
        k, v, pos = k[:, -n:], v[:, -n:], pos[-n:]
        next_slot = jnp.zeros((), jnp.int32)
        slot_pos = pos.astype(jnp.int32)
    else:
        padn = n - S
        k = jnp.pad(k, ((0, 0), (0, padn), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padn), (0, 0), (0, 0)))
        slot_pos = jnp.concatenate(
            [pos.astype(jnp.int32), jnp.full((padn,), -1, jnp.int32)])
        next_slot = jnp.array(S % n, jnp.int32)
    return {"k": k, "v": v, "slot_pos": slot_pos,
            "next_slot": next_slot}


# ---------------------------------------------------------------------------
# trunk


def _embed(params, cfg: ModelConfig, batch, ctx):
    dt = _dtype(cfg)
    if cfg.family == "audio":
        x = batch["frame_embeddings"].astype(dt) @ params["embed_proj"]
    else:
        x = params["embed"][batch["tokens"]].astype(dt)
    x = x * jnp.asarray(jnp.sqrt(cfg.d_model), dt)
    if cfg.pos_embedding == "sinusoidal":
        S = x.shape[1]
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.arange(S)[None, :]
        x = x + L.sinusoidal_pos(pos, cfg.d_model).astype(dt)
    return constrain(x, ctx, "batch", "sp", None)


def _encoder_states(params, cfg: ModelConfig, batch, ctx):
    if cfg.family != "vlm":
        return None
    enc = batch["encoder_embeddings"].astype(_dtype(cfg))
    if "enc_proj" in params:
        enc = enc @ params["enc_proj"]
    return constrain(enc, ctx, "batch", None, None)


def _head(params, cfg: ModelConfig, x, ctx):
    xn = (W.layer_norm(params["final_norm"], x)
          if RWKV in cfg.block_pattern
          else L.rms_norm(params["final_norm"], x))
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    # explicit upcast (not preferred_element_type): keeps the residual
    # cotangent bf16 — see layers._attend
    logits = jnp.einsum("bsd,dv->bsv", xn.astype(jnp.float32),
                        head.astype(jnp.float32))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    # vocab column-parallel in BOTH sharding modes ("sp" always resolves
    # to the model axis): unsharded f32 logits are 37 GiB for train_4k
    return constrain(logits, ctx, "batch", None, "sp")


def forward(params, cfg: ModelConfig, batch, ctx: Optional[ShardingCtx] = None,
            collect_cache: bool = False, cache_len: int = 0):
    """Full-sequence forward. Returns (logits, aux, cache-or-None)."""
    x = _embed(params, cfg, batch, ctx)
    enc = _encoder_states(params, cfg, batch, ctx)
    B, S = x.shape[0], x.shape[1]
    q_pos = jnp.arange(S)[None, :]
    period = cfg.pattern_period

    def one_layer(kind):
        def fn(lp, x):
            return apply_layer(
                lp, cfg, kind, x, enc=enc, q_pos=q_pos, ctx=ctx, state=None,
                decode=False, collect_cache=collect_cache,
                cache_len=cache_len)
        # remat each LAYER (not the whole period): backward then holds one
        # layer's transients at a time — a 6-layer gemma3 period body kept
        # ~50 GiB of f32 transients live otherwise.  (prevent_cse stays at
        # its default True: =False let CSE defeat remat, +45% temp memory —
        # refuted hypothesis, see EXPERIMENTS.md §Perf.)
        return (jax.checkpoint(fn) if cfg.remat else fn)

    layer_fns = [one_layer(kind) for kind in cfg.block_pattern]

    def run_period(x_aux, pparams):
        x, aux = x_aux
        states = {}
        for i in range(len(cfg.block_pattern)):
            x, a, st = layer_fns[i](pparams[f"layer{i}"], x)
            aux = aux + a
            states[f"layer{i}"] = st
        return (x, aux), states

    aux = jnp.zeros((), jnp.float32)
    cache = {}
    if cfg.num_full_periods and cfg.unroll_for_costing:
        states_list = []
        xa = (x, aux)
        for pi in range(cfg.num_full_periods):
            pparams = jax.tree.map(lambda l: l[pi], params["blocks"])
            xa, st = run_period(xa, pparams)
            states_list.append(st)
        (x, aux) = xa
        if collect_cache:
            cache["blocks"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *states_list)
    elif cfg.num_full_periods:
        (x, aux), period_states = jax.lax.scan(
            run_period, (x, aux), params["blocks"])
        if collect_cache:
            cache["blocks"] = period_states
    for i in range(cfg.num_remainder_layers):
        kind = cfg.block_pattern[i]
        x, a, st = apply_layer(
            params[f"rem{i}"], cfg, kind, x, enc=enc, q_pos=q_pos, ctx=ctx,
            collect_cache=collect_cache, cache_len=cache_len)
        aux = aux + a
        if collect_cache:
            cache[f"rem{i}"] = st
    logits = _head(params, cfg, x, ctx)
    if collect_cache:
        cache["pos"] = jnp.asarray(S, jnp.int32)
        return logits, aux, cache
    return logits, aux, None


CE_CHUNK = 512


def loss_fn(params, cfg: ModelConfig, batch,
            ctx: Optional[ShardingCtx] = None, label_smoothing: float = 0.0):
    S = batch["targets"].shape[1]
    big = S * cfg.vocab_size > (1 << 24)
    if big and not cfg.unroll_for_costing:
        # chunked head+CE: full [B, S, V] f32 logits (and their backward
        # copies: probs, the head-grad transpose) were 3-4 x 4.3 GiB live
        # buffers for gemma3 train_4k — §Perf pair 2
        x, aux = forward_hidden(params, cfg, batch, ctx)
        loss = _chunked_ce(params, cfg, x, batch["targets"],
                           batch.get("loss_mask"), ctx, label_smoothing)
    else:
        logits, aux, _ = forward(params, cfg, batch, ctx)
        loss = L.softmax_cross_entropy(
            logits, batch["targets"], batch.get("loss_mask"),
            label_smoothing)
    total = loss + aux
    return total, {"ce_loss": loss, "aux_loss": aux}


def forward_hidden(params, cfg: ModelConfig, batch, ctx=None):
    """Trunk only: final *hidden* (pre-head) + aux loss."""
    logits_unused = None
    x = _embed(params, cfg, batch, ctx)
    enc = _encoder_states(params, cfg, batch, ctx)
    q_pos = jnp.arange(x.shape[1])[None, :]

    def one_layer(kind):
        def fn(lp, x):
            return apply_layer(lp, cfg, kind, x, enc=enc, q_pos=q_pos,
                               ctx=ctx)
        return jax.checkpoint(fn) if cfg.remat else fn

    layer_fns = [one_layer(kind) for kind in cfg.block_pattern]

    def run_period(x_aux, pparams):
        x, aux = x_aux
        for i in range(len(cfg.block_pattern)):
            x, a, _ = layer_fns[i](pparams[f"layer{i}"], x)
            aux = aux + a
        return (x, aux), None

    aux = jnp.zeros((), jnp.float32)
    if cfg.num_full_periods:
        (x, aux), _ = jax.lax.scan(run_period, (x, aux), params["blocks"])
    for i in range(cfg.num_remainder_layers):
        x, a, _ = apply_layer(params[f"rem{i}"], cfg,
                              cfg.block_pattern[i], x, enc=enc,
                              q_pos=q_pos, ctx=ctx)
        aux = aux + a
    return x, aux


def _chunked_ce(params, cfg: ModelConfig, x, targets, mask, ctx,
                label_smoothing: float, chunk: int = CE_CHUNK):
    B, S, d = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask if mask is not None
                       else jnp.ones((B, S), jnp.float32),
                       ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    n = (S + pad) // chunk

    def to_chunks(a):
        return a.reshape((B, n, chunk) + a.shape[2:]).swapaxes(0, 1)

    @jax.checkpoint
    def body(args):
        xi, ti, mi = args
        logits = _head(params, cfg, xi, ctx)
        return L.softmax_cross_entropy_sums(logits, ti, mi, label_smoothing)

    sums, wsums = jax.lax.map(body, (to_chunks(x), to_chunks(targets),
                                     to_chunks(mask)))
    return sums.sum() / jnp.maximum(wsums.sum(), 1.0)


def serve_step(params, cfg: ModelConfig, cache, batch,
               ctx: Optional[ShardingCtx] = None):
    """One decode step: batch['tokens'] [B,1] (audio: frame_embeddings).
    Returns (logits [B,1,V], new cache)."""
    x = _embed(params, cfg,
               {**batch, "positions": cache["pos"][None, None]}, ctx)
    B = x.shape[0]
    pos = cache["pos"]
    q_pos = jnp.full((B, 1), pos, jnp.int32)

    def run_period(x, scanned):
        pparams, pstate = scanned
        new_states = {}
        for i, kind in enumerate(cfg.block_pattern):
            xx, _, st = apply_layer(
                pparams[f"layer{i}"], cfg, kind, x, q_pos=q_pos, ctx=ctx,
                state=pstate[f"layer{i}"], decode=True)
            x = xx
            new_states[f"layer{i}"] = st
        return x, new_states

    new_cache = {}
    if cfg.num_full_periods and cfg.unroll_for_costing:
        states_list = []
        for pi in range(cfg.num_full_periods):
            scanned = jax.tree.map(lambda l: l[pi],
                                   (params["blocks"], cache["blocks"]))
            x, st = run_period(x, scanned)
            states_list.append(st)
        new_cache["blocks"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *states_list)
    elif cfg.num_full_periods:
        x, states = jax.lax.scan(
            run_period, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = states
    for i in range(cfg.num_remainder_layers):
        kind = cfg.block_pattern[i]
        x, _, st = apply_layer(
            params[f"rem{i}"], cfg, kind, x, q_pos=q_pos, ctx=ctx,
            state=cache[f"rem{i}"], decode=True)
        new_cache[f"rem{i}"] = st
    new_cache["pos"] = pos + 1
    logits = _head(params, cfg, x, ctx)
    return logits, new_cache
