"""RWKV6 ("Finch", arXiv:2404.05892) time-mix and channel-mix blocks.

The WKV recurrence has data-dependent per-channel decay:

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (S in R^{hd x hd} per head)
    y_t = r_t S_{t-1} + (r_t . (u * k_t)) v_t

TPU adaptation (DESIGN.md §3): instead of a step-by-step scan we use a
*chunked* formulation — within a chunk of C tokens all pairwise decay
products are computed in log space (numerically safe: every exponent is
<= 0) and contracted with matmuls that map onto the MXU; the inter-chunk
state is carried by a scan over T/C chunks.  ``repro.kernels.rwkv_scan``
implements the same math as a Pallas kernel; ``repro.kernels.ref`` holds
the naive-recurrence oracle.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import ShardingCtx, constrain
from repro.models.layers import dense_init, group_norm

WKV_CHUNK = 64
LORA_RANK = 32


def layer_norm_init(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype=dtype),
            "bias": jnp.zeros((dim,), dtype=dtype)}


def layer_norm(params, x, eps=1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------


def time_mix_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    H, hd = cfg.num_heads, cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    r = LORA_RANK
    return {
        "maa_x": jnp.zeros((d,), dtype),
        "maa_rkvwg": jnp.zeros((5, d), dtype),
        "maa_A": dense_init(ks[0], (d, 5 * r), scale=0.01, dtype=dtype),
        "maa_B": dense_init(ks[1], (5, r, d), scale=0.01, dtype=dtype),
        "w_base": jnp.full((d,), -1.0, dtype=jnp.float32),   # decay bias
        "w_A": dense_init(ks[2], (d, 64), scale=0.01, dtype=dtype),
        "w_B": dense_init(ks[3], (64, d), scale=0.01, dtype=dtype),
        "u": (jax.random.normal(ks[4], (H, hd)) * 0.1).astype(jnp.float32),
        "W_r": dense_init(ks[5], (d, H * hd), dtype=dtype),
        "W_k": dense_init(ks[6], (d, H * hd), dtype=dtype),
        "W_v": dense_init(ks[7], (d, H * hd), dtype=dtype),
        "W_g": dense_init(ks[8], (d, H * hd), dtype=dtype),
        "W_o": dense_init(ks[9], (H * hd, d), dtype=dtype),
        "gn_scale": jnp.ones((H * hd,), dtype),
        "gn_bias": jnp.zeros((H * hd,), dtype),
    }


def channel_mix_init(key, cfg: ModelConfig, dtype):
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "maa_k": jnp.zeros((d,), dtype),
        "maa_r": jnp.zeros((d,), dtype),
        "W_k": dense_init(ks[0], (d, dff), dtype=dtype),
        "W_v": dense_init(ks[1], (dff, d), dtype=dtype),
        "W_r": dense_init(ks[2], (d, d), dtype=dtype),
    }


def _token_shift(x, shift_state):
    """x [B,T,d]; shift_state [B,d] (last token of previous segment)."""
    prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1]], axis=1)
    return prev


def _ddlerp(p, x, prev):
    """RWKV6 data-dependent token-shift interpolation -> 5 mixed streams."""
    sx = prev - x
    xxx = x + sx * p["maa_x"]
    B, T, d = x.shape
    r = LORA_RANK
    lora = jnp.tanh(xxx @ p["maa_A"]).reshape(B, T, 5, r)
    adj = jnp.einsum("btfr,frd->fbtd", lora, p["maa_B"])     # [5,B,T,d]
    mixed = x[None] + sx[None] * (p["maa_rkvwg"][:, None, None, :] + adj)
    return mixed  # [5, B, T, d] -> r,k,v,w,g order


def wkv_chunked(r, k, v, w, u, state, chunk: int = WKV_CHUNK,
                unroll: bool = False):
    """Chunked WKV recurrence.

    r,k,v,w: [B,T,H,hd] (w = per-step decay in (0,1)); u [H,hd];
    state [B,H,hd,hd] f32.  Returns (y [B,T,H,hd], final state).
    All decay products are exp(sum of negative logs) -> no overflow.
    """
    B, T, H, hd = r.shape
    C = chunk
    if unroll:
        # cost-accounting: cap the straight-line chunk count at 128 —
        # unrolling 512+ chunk bodies made 32k-prefill counting compiles
        # take tens of minutes, and the WKV share of rwkv6 FLOPs is ~1%
        C = max(C, -(-T // 128))
        C += (-C) % 16
    pad = (-T) % C
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    Tp = T + pad
    N = Tp // C

    def to_chunks(a):
        return a.reshape(B, N, C, H, hd).transpose(1, 0, 3, 2, 4)  # [N,B,H,C,hd]

    r_, k_, v_ = to_chunks(r).astype(jnp.float32), to_chunks(k).astype(
        jnp.float32), to_chunks(v).astype(jnp.float32)
    logw = jnp.log(jnp.clip(to_chunks(w).astype(jnp.float32), 1e-8, 1.0))
    lc = jnp.cumsum(logw, axis=3)                       # [N,B,H,C,hd]
    lc_total = lc[:, :, :, -1:, :]                      # [N,B,H,1,hd]

    tri = jnp.tril(jnp.ones((C, C), dtype=bool), k=-1)  # strict lower

    @jax.checkpoint
    def body(S, xs):
        # rematerialized: the [B,H,C,C,hd] pairwise-decay tensor would
        # otherwise be stacked across all T/C chunks by the scan backward
        # (10 GiB for rwkv6-3b train_4k)
        rc, kc, vc, lcc, lwc, lct = xs
        # inter-chunk: y_t += (r_t * prod_{u<=t-1} w_u) @ S
        rdec = rc * jnp.exp(lcc - lwc)                  # exp(lc_{t-1})
        y_inter = jnp.einsum("bhtd,bhdv->bhtv", rdec, S)
        # intra-chunk pairwise decays, log-space (always <= 0 for s < t)
        ldiff = (lcc - lwc)[:, :, :, None, :] - lcc[:, :, None, :, :]
        pair = jnp.exp(jnp.where(tri[None, None, :, :, None], ldiff, -1e30))
        A = jnp.einsum("bhtd,bhsd,bhtsd->bhts", rc, kc, pair)
        diag = jnp.einsum("bhtd,hd,bhtd->bht", rc, u.astype(jnp.float32), kc)
        A = A + diag[..., None] * jnp.eye(C)[None, None]
        y_intra = jnp.einsum("bhts,bhsv->bhtv", A, vc)
        # state to next chunk
        kdec = kc * jnp.exp(lct - lcc)
        S_new = jnp.einsum("bhtd,bhtv->bhdv", kdec, vc) \
            + jnp.exp(lct)[:, :, 0, :, None] * S
        return S_new, y_inter + y_intra

    xs = (r_, k_, v_, lc, logw, lc_total)
    if unroll:   # cost-accounting: straight-line HLO (see configs.base)
        S_cur = state.astype(jnp.float32)
        ys_list = []
        for ci in range(N):
            S_cur, y_c = body(S_cur, jax.tree.map(lambda a: a[ci], xs))
            ys_list.append(y_c)
        S_final, ys = S_cur, jnp.stack(ys_list)
    else:
        S_final, ys = jax.lax.scan(body, state.astype(jnp.float32), xs)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, Tp, H, hd)[:, :T]
    return y.astype(r.dtype), S_final


def wkv_step(r, k, v, w, u, state):
    """Single decode step. r,k,v,w [B,H,hd]; state [B,H,hd,hd] f32."""
    r, k, v, w = (a.astype(jnp.float32) for a in (r, k, v, w))
    y = jnp.einsum("bhd,bhdv->bhv", r, state) \
        + jnp.einsum("bhd,hd,bhd->bh", r, u.astype(jnp.float32),
                     k)[..., None] * v
    state = w[..., None] * state + jnp.einsum("bhd,bhv->bhdv", k, v)
    return y, state


def time_mix(p, cfg: ModelConfig, x, shift_state, wkv_state,
             ctx: Optional[ShardingCtx] = None):
    """x [B,T,d] -> (y, new_shift [B,d], new_wkv_state)."""
    B, T, d = x.shape
    H, hd = cfg.num_heads, cfg.rwkv_head_dim
    prev = _token_shift(x, shift_state)
    xr, xk, xv, xw, xg = _ddlerp(p, x, prev)

    def heads(a):
        return a.reshape(B, T, H, hd)

    r = heads(xr @ p["W_r"])
    k = heads(xk @ p["W_k"])
    v = heads(xv @ p["W_v"])
    g = jax.nn.silu(xg @ p["W_g"])
    w_raw = p["w_base"] + jnp.tanh(xw @ p["w_A"]).astype(jnp.float32) \
        @ p["w_B"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(jnp.clip(w_raw, -8.0, 1.0)))   # decay in (0,1)
    w = heads(w)
    r = constrain(r, ctx, "batch", None, "model", None)

    y, wkv_state = wkv_chunked(r, k, v, w, p["u"], wkv_state,
                               unroll=cfg.unroll_for_costing)
    y = y.reshape(B, T, H * hd)
    y = group_norm(y, H, scale=p["gn_scale"], bias=p["gn_bias"])
    y = (y * g) @ p["W_o"]
    return y, x[:, -1], wkv_state


def time_mix_step(p, cfg: ModelConfig, x, shift_state, wkv_state):
    """Decode: x [B,1,d]."""
    B, _, d = x.shape
    H, hd = cfg.num_heads, cfg.rwkv_head_dim
    prev = shift_state[:, None, :]
    xr, xk, xv, xw, xg = _ddlerp(p, x, prev)
    sq = lambda a: a.reshape(B, H, hd)
    r, k, v = sq(xr @ p["W_r"]), sq(xk @ p["W_k"]), sq(xv @ p["W_v"])
    g = jax.nn.silu(xg @ p["W_g"])[:, 0]
    w_raw = p["w_base"] + jnp.tanh(xw @ p["w_A"]).astype(jnp.float32) \
        @ p["w_B"].astype(jnp.float32)
    w = sq(jnp.exp(-jnp.exp(jnp.clip(w_raw, -8.0, 1.0))))
    y, wkv_state = wkv_step(r, k, v, w, p["u"], wkv_state)
    y = y.reshape(B, H * hd).astype(x.dtype)
    y = group_norm(y, H, scale=p["gn_scale"], bias=p["gn_bias"])
    y = (y * g) @ p["W_o"]
    return y[:, None, :], x[:, -1], wkv_state


def channel_mix(p, x, shift_state, ctx: Optional[ShardingCtx] = None):
    prev = _token_shift(x, shift_state)
    sx = prev - x
    xk = x + sx * p["maa_k"]
    xr = x + sx * p["maa_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["W_k"]))
    kk = constrain(kk, ctx, "batch", None, "sp")
    out = jax.nn.sigmoid(xr @ p["W_r"]) * (kk @ p["W_v"])
    return out, x[:, -1]
