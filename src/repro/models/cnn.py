"""The paper's vision models (Section VI-A), pure JAX.

- paper_cnn: 2x[conv3x3-32]+pool+drop(0.2), 2x[conv3x3-64]+pool+drop(0.3),
  FC-120-ReLU, FC-num_classes.  Input NHWC 32x32x3 (paper: 20x3x32x32
  batches).
- resnet18_gn: ResNet-18 with every BatchNorm replaced by GroupNorm [50]
  (CIFAR stem: 3x3 stride-1 conv, no max-pool).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.paper_cnn import CNNConfig


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout)) * math.sqrt(2 / fan_in)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn(x, scale, bias, groups=8, eps=1e-5):
    n, h, wd, c = x.shape
    g = x.reshape(n, h, wd, groups, c // groups).astype(jnp.float32)
    mean = g.mean((1, 2, 4), keepdims=True)
    var = g.var((1, 2, 4), keepdims=True)
    g = (g - mean) * jax.lax.rsqrt(var + eps)
    return (g.reshape(x.shape) * scale + bias).astype(x.dtype)


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def _dropout(x, rate, rng):
    if rng is None or rate == 0.0:
        return x
    keep = jax.random.bernoulli(rng, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


# ---------------------------------------------------------------------------
# paper CNN


def init_paper_cnn(key, cfg: CNNConfig):
    ks = jax.random.split(key, 8)
    # width may be fractional (benchmark-scale micro models): channel
    # counts round to >= 1
    c32 = max(1, int(round(32 * cfg.width)))
    c64 = max(1, int(round(64 * cfg.width)))
    flat = (cfg.image_size // 4) ** 2 * c64
    return {
        "c1": _conv_init(ks[0], 3, 3, cfg.channels, c32),
        "c2": _conv_init(ks[1], 3, 3, c32, c32),
        "c3": _conv_init(ks[2], 3, 3, c32, c64),
        "c4": _conv_init(ks[3], 3, 3, c64, c64),
        "fc1": jax.random.normal(ks[4], (flat, 120)) * math.sqrt(2 / flat),
        "b1": jnp.zeros((120,)),
        "fc2": jax.random.normal(ks[5], (120, cfg.num_classes)) * 0.1,
        "b2": jnp.zeros((cfg.num_classes,)),
    }


def paper_cnn_forward(params, cfg: CNNConfig, images, rng=None):
    """images [B,H,W,C] f32 -> logits [B,num_classes]."""
    r1 = r2 = None
    if rng is not None and cfg.dropout:
        r1, r2 = jax.random.split(rng)
    x = jax.nn.relu(_conv(images, params["c1"]))
    x = jax.nn.relu(_conv(x, params["c2"]))
    x = _maxpool2(x)
    x = _dropout(x, 0.2, r1)
    x = jax.nn.relu(_conv(x, params["c3"]))
    x = jax.nn.relu(_conv(x, params["c4"]))
    x = _maxpool2(x)
    x = _dropout(x, 0.3, r2)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"] + params["b1"])
    return x @ params["fc2"] + params["b2"]


# ---------------------------------------------------------------------------
# ResNet18-GN


def _block_init(key, cin, cout, stride):
    ks = jax.random.split(key, 3)
    p = {
        "conv1": _conv_init(ks[0], 3, 3, cin, cout),
        "gn1_s": jnp.ones((cout,)), "gn1_b": jnp.zeros((cout,)),
        "conv2": _conv_init(ks[1], 3, 3, cout, cout),
        "gn2_s": jnp.ones((cout,)), "gn2_b": jnp.zeros((cout,)),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[2], 1, 1, cin, cout)
        p["gnp_s"] = jnp.ones((cout,))
        p["gnp_b"] = jnp.zeros((cout,))
    return p


def _block_fwd(p, x, stride, groups):
    h = jax.nn.relu(_gn(_conv(x, p["conv1"], stride), p["gn1_s"], p["gn1_b"],
                        groups))
    h = _gn(_conv(h, p["conv2"]), p["gn2_s"], p["gn2_b"], groups)
    if "proj" in p:
        x = _gn(_conv(x, p["proj"], stride), p["gnp_s"], p["gnp_b"], groups)
    return jax.nn.relu(x + h)


STAGES = [(64, 1), (128, 2), (256, 2), (512, 2)]


def init_resnet18_gn(key, cfg: CNNConfig):
    ks = jax.random.split(key, 11)
    params = {
        "stem": _conv_init(ks[0], 3, 3, cfg.channels, 64),
        "gn_s": jnp.ones((64,)), "gn_b": jnp.zeros((64,)),
        "fc": jax.random.normal(ks[1], (512, cfg.num_classes)) * 0.05,
        "fc_b": jnp.zeros((cfg.num_classes,)),
    }
    cin = 64
    i = 2
    for si, (cout, stride) in enumerate(STAGES):
        for bi in range(2):
            params[f"s{si}b{bi}"] = _block_init(
                ks[i], cin, cout, stride if bi == 0 else 1)
            cin = cout
            i += 1
    return params


def resnet18_gn_forward(params, cfg: CNNConfig, images, rng=None):
    g = cfg.gn_groups
    x = jax.nn.relu(_gn(_conv(images, params["stem"]), params["gn_s"],
                        params["gn_b"], g))
    for si, (cout, stride) in enumerate(STAGES):
        for bi in range(2):
            x = _block_fwd(params[f"s{si}b{bi}"], x,
                           stride if bi == 0 else 1, g)
    x = x.mean(axis=(1, 2))
    return x @ params["fc"] + params["fc_b"]


def init_cnn(key, cfg: CNNConfig):
    if cfg.kind == "paper_cnn":
        return init_paper_cnn(key, cfg)
    return init_resnet18_gn(key, cfg)


def cnn_forward(params, cfg: CNNConfig, images, rng=None):
    if cfg.kind == "paper_cnn":
        return paper_cnn_forward(params, cfg, images, rng)
    return resnet18_gn_forward(params, cfg, images, rng)
