"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

    i_t = sigmoid(W_i x_t + b_i)                      (input gate)
    r_t = sigmoid(W_r x_t + b_r)                      (recurrence gate)
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

TPU adaptation: the diagonal linear recurrence is evaluated with
``jax.lax.associative_scan`` (log-depth, maps onto VPU elementwise ops)
for train/prefill, and a single fused step for decode.  A Pallas kernel
(`repro.kernels.rglru_scan`) implements the sequential-grid variant.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import ShardingCtx, constrain
from repro.models.layers import dense_init

RG_LRU_C = 8.0


def recurrent_block_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    lru = cfg.lru_width or d
    W = cfg.conv1d_width
    ks = jax.random.split(key, 8)
    # Lambda init so that a^c in [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[0], (lru,), minval=0.9 ** 2, maxval=0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / RG_LRU_C))     # softplus^-1
    return {
        "w_x": dense_init(ks[1], (d, lru), dtype=dtype),
        "w_gate": dense_init(ks[2], (d, lru), dtype=dtype),
        "conv_kernel": (jax.random.normal(ks[3], (W, lru)) * 0.1).astype(dtype),
        "conv_bias": jnp.zeros((lru,), dtype),
        "W_i": dense_init(ks[4], (lru, lru), dtype=dtype),
        "b_i": jnp.zeros((lru,), jnp.float32),
        "W_r": dense_init(ks[5], (lru, lru), dtype=dtype),
        "b_r": jnp.zeros((lru,), jnp.float32),
        "Lambda": lam.astype(jnp.float32),
        "w_out": dense_init(ks[6], (lru, d), dtype=dtype),
    }


def _causal_conv1d(kernel, bias, x, conv_state):
    """Depthwise causal conv. x [B,T,lru]; conv_state [B,W-1,lru] history."""
    W = kernel.shape[0]
    xx = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(xx[:, W - 1 - i: xx.shape[1] - i] * kernel[W - 1 - i]
              for i in range(W))
    new_state = xx[:, -(W - 1):] if W > 1 else conv_state
    return out + bias, new_state


def _rg_lru_coeffs(p, x):
    """x [B,T,lru] -> (a, b) with h_t = a_t h_{t-1} + b_t, all f32."""
    xf = x.astype(jnp.float32)
    i = jax.nn.sigmoid(xf @ p["W_i"].astype(jnp.float32) + p["b_i"])
    r = jax.nn.sigmoid(xf @ p["W_r"].astype(jnp.float32) + p["b_r"])
    log_a = -RG_LRU_C * jax.nn.softplus(p["Lambda"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0, 1.0)) * (i * xf)
    return a, b


def rg_lru_scan(p, x, h0):
    """Associative scan over time. x [B,T,lru]; h0 [B,lru] f32."""
    a, b = _rg_lru_coeffs(p, x)
    # fold h0 into the first step: h_1 = a_1 h_0 + b_1
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_step(p, x, h0):
    """x [B,1,lru]; h0 [B,lru] f32."""
    a, b = _rg_lru_coeffs(p, x)
    h = a[:, 0] * h0 + b[:, 0]
    return h[:, None, :].astype(x.dtype), h


def recurrent_block(p, cfg: ModelConfig, x, state,
                    ctx: Optional[ShardingCtx] = None, decode: bool = False):
    """Griffin recurrent block. x [B,T,d];
    state = {'h': [B,lru] f32, 'conv': [B,W-1,lru] f32}."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    xb = x @ p["w_x"]
    xb = constrain(xb, ctx, "batch", None, "sp")
    xb, conv_state = _causal_conv1d(p["conv_kernel"], p["conv_bias"], xb,
                                    state["conv"])
    if decode:
        h, h_last = rg_lru_step(p, xb, state["h"])
    else:
        h, h_last = rg_lru_scan(p, xb, state["h"])
    out = (h * gate) @ p["w_out"]
    return out, {"h": h_last, "conv": conv_state.astype(jnp.float32)}


def init_recurrent_state(cfg: ModelConfig, batch: int):
    lru = cfg.lru_width or cfg.d_model
    W = cfg.conv1d_width
    return {"h": jnp.zeros((batch, lru), jnp.float32),
            "conv": jnp.zeros((batch, W - 1, lru), jnp.float32)}
