"""Shared neural-net primitives for the model zoo (pure JAX, functional).

Parameters are plain dict pytrees.  Every apply function takes the config
and an optional ShardingCtx.  Attention uses a query-chunked (FlashAttention
-style online) formulation above ``CHUNK_THRESHOLD`` so 32k prefill never
materializes an S x S score matrix; the Pallas kernel in
``repro.kernels.flash_attention`` implements the same contract for TPU.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import ShardingCtx, constrain

CHUNK_THRESHOLD = 2048   # use query-chunked attention above this seq len
Q_CHUNK = 512

# ---------------------------------------------------------------------------
# init helpers


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    if len(shape) == 3:           # [d, H, hd] fused head projections
        fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms


def rms_norm_init(dim, dtype):
    return {"scale": jnp.zeros((dim,), dtype=dtype)}


def rms_norm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


def group_norm(x, num_groups, eps: float = 1e-5, scale=None, bias=None):
    """GroupNorm over the last dim (used by RWKV6 wkv output)."""
    dtype = x.dtype
    d = x.shape[-1]
    g = x.reshape(x.shape[:-1] + (num_groups, d // num_groups)).astype(jnp.float32)
    mean = g.mean(-1, keepdims=True)
    var = g.var(-1, keepdims=True)
    g = (g - mean) * jax.lax.rsqrt(var + eps)
    g = g.reshape(x.shape)
    if scale is not None:
        g = g * scale.astype(jnp.float32)
    if bias is not None:
        g = g + bias.astype(jnp.float32)
    return g.astype(dtype)


# ---------------------------------------------------------------------------
# positions


def rope(x, positions, theta: float):
    """x [..., S, H, hd]; positions [..., S] (broadcastable).

    Angles are computed in f32 but sin/cos are cast to x.dtype BEFORE the
    rotation: multiplying bf16 activations by f32 tables promotes the full
    q/k tensors to f32, and under GSPMD the GQA-expand all-gather then
    moves 2x the bytes (7 GiB/step extra for qwen2 train_4k — §Perf)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None, None].astype(jnp.float32) * freq
    sin = jnp.sin(angles).astype(x.dtype)
    cos = jnp.cos(angles).astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def sinusoidal_pos(positions, dim):
    half = dim // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                   * (math.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention


def attention_params_init(key, cfg: ModelConfig, dtype, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, KV, hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, KV, hd), dtype=dtype),
        "wo": dense_init(ks[3], (H, hd, d), scale=1.0 / math.sqrt(H * hd),
                         dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype=dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype=dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype=dtype)
    if cfg.qk_norm:
        p["q_norm"] = rms_norm_init(hd, dtype)
        p["k_norm"] = rms_norm_init(hd, dtype)
    if cross:
        p["gate"] = jnp.zeros((), dtype=jnp.float32)  # tanh-gated cross attn
    return p


def _expand_kv(k, H):
    """[B,T,KV,hd] -> [B,T,H,hd] by group broadcast (GQA)."""
    B, T, KV, hd = k.shape
    G = H // KV
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, T, KV, G, hd))
    return k.reshape(B, T, H, hd)


def _mask_bias(q_pos, k_pos, window: int, causal: bool):
    """Additive f32 bias [q, k] from position vectors."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    ok &= k_pos[None, :] >= 0   # slots with pos -1 are invalid (ring buffer)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _attend(q, k, v, bias):
    """q [B,S,H,hd], k/v [B,T,H,hd], bias [S,T] or [B,S,T]-broadcastable.

    q/k are upcast EXPLICITLY rather than via preferred_element_type: the
    VJP of a bf16-in/f32-out dot emits f32 cotangents that flow back
    through rope/projections into the residual stream un-converted —
    every layer's [tokens, d_model] cotangent then lives in f32 (the
    ~50 GiB gemma3 temp blowup, §Perf pair 2).  An explicit astype puts a
    convert on the backward path, so cotangents re-enter bf16 here."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = scores + bias[..., None, :, :] if bias.ndim == 3 else scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)
    return out


def multihead_attention(params, cfg: ModelConfig, x, *, kv_x=None,
                        q_pos=None, k_pos=None, causal=True, window=0,
                        rope_theta=None, ctx: Optional[ShardingCtx] = None,
                        cache=None, cache_fixed_kv=False):
    """General GQA attention.

    x [B,S,d]; kv_x defaults to x (self attention).  If ``cache`` is given
    we are decoding: S==1, cache holds {'k','v','slot_pos'} and is updated
    (unless cache_fixed_kv, e.g. cross-attention KV precomputed at prefill).
    Returns (out [B,S,d], new_cache).
    """
    B, S, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kv_x = x if kv_x is None else kv_x

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    fresh_kv = not (cache is not None and cache_fixed_kv)
    if fresh_kv:
        k = jnp.einsum("bsd,dhk->bshk", kv_x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", kv_x, params["wv"])
        if "bk" in params:
            k, v = k + params["bk"], v + params["bv"]
        new_cache = None
    else:
        # cross-attention KV precomputed at prefill (already normed + roped)
        k, v = cache["k"], cache["v"]
        new_cache = cache

    if "q_norm" in params:
        q = rms_norm(params["q_norm"], q)
        if fresh_kv:
            k = rms_norm(params["k_norm"], k)

    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    if cfg.pos_embedding == "rope" and q_pos is not None:
        q = rope(q, q_pos, theta)
        if fresh_kv:
            k = rope(k, k_pos if k_pos is not None else q_pos, theta)

    if cache is not None and not cache_fixed_kv:
        # decode: write new kv into ring/linear buffer at slot
        slot = cache["next_slot"]          # scalar int32
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1) \
            if False else cache["k"].at[:, slot].set(k[:, 0])
        v_cache = cache["v"].at[:, slot].set(v[:, 0])
        slot_pos = cache["slot_pos"].at[slot].set(q_pos[0, 0] if q_pos.ndim == 2
                                                  else q_pos[0])
        wsize = cache["k"].shape[1]
        new_cache = {
            "k": k_cache, "v": v_cache, "slot_pos": slot_pos,
            "next_slot": (slot + 1) % wsize,
        }
        k, v = k_cache, v_cache
        k_pos_eff = slot_pos[None, :]
    else:
        k_pos_eff = (k_pos if k_pos is not None else q_pos)
        if k_pos_eff is not None and k_pos_eff.ndim == 1:
            k_pos_eff = k_pos_eff[None, :]

    k_raw, v_raw = k, v            # pre-expansion (post-norm/rope) for caches
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    context_parallel = ctx is not None and (not ctx.tp or ctx.hybrid)
    if context_parallel and S > 1:
        # queries sharded over seq, (small GQA) KV replicated/gathered —
        # context parallelism; no S<->head resharding anywhere
        q = constrain(q, ctx, "batch", "sp", None, None)
        k = constrain(k, ctx, "batch", None, None, None)
        v = constrain(v, ctx, "batch", None, None, None)
    else:
        q = constrain(q, ctx, "batch", None, "model", None)
        k = constrain(k, ctx, "batch", "seq" if S == 1 else None,
                      "model", None)
        v = constrain(v, ctx, "batch", "seq" if S == 1 else None,
                      "model", None)

    qp = q_pos if q_pos is not None else jnp.arange(S)
    if qp.ndim == 1:
        qp = qp[None, :]
    kp = k_pos_eff if k_pos_eff is not None else jnp.arange(k.shape[1])[None, :]

    if S == 1 or k.shape[1] <= CHUNK_THRESHOLD or cfg.unroll_for_costing:
        bias = jax.vmap(lambda a, b: _mask_bias(a, b, window, causal))(
            jnp.broadcast_to(qp, (B, S)), jnp.broadcast_to(kp, (B, k.shape[1])))
        out = _attend(q, k, v, bias)
    else:
        out = _chunked_attend(q, k, v, qp, kp, window, causal)

    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if "gate" in params:
        out = out * jnp.tanh(params["gate"]).astype(out.dtype)
    return out, new_cache, (k_raw, v_raw)


def _chunked_attend(q, k, v, q_pos, k_pos, window, causal):
    """Query-chunked attention: never materializes [S,T] for full S.

    q [B,S,H,hd]; scans over S in Q_CHUNK blocks.  For sliding-window
    layers each query block only visits its [window + chunk] KV span
    (positions here are contiguous sequence indices): ~2.7x less attention
    compute and score memory on gemma3's 5-of-6 local layers (§Perf).
    Flash-style blocking of the full KV axis is the Pallas kernel's job on
    TPU; at the XLA level the [chunk, span] slice is memory-safe."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    q_pos = jnp.broadcast_to(q_pos, (B, S))
    nchunk = -(-S // Q_CHUNK)
    pad = nchunk * Q_CHUNK - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
    qc = q.reshape(B, nchunk, Q_CHUNK, H, hd).transpose(1, 0, 2, 3, 4)
    qpc = q_pos.reshape(B, nchunk, Q_CHUNK).transpose(1, 0, 2)

    windowed = window > 0 and causal and T > window + Q_CHUNK
    kv_span = min(window + Q_CHUNK, T)
    starts = jnp.clip(jnp.arange(nchunk) * Q_CHUNK + Q_CHUNK - kv_span,
                      0, T - kv_span)

    @jax.checkpoint
    def body(args):
        # rematerialized in backward: the probs block is never stored
        qi, qpi, start = args
        if windowed:
            ki = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
            kp = (start + jnp.arange(kv_span))[None, :]
        else:
            ki, vi = k, v
            kp = k_pos
        bias = jax.vmap(lambda a, b: _mask_bias(a, b, window, causal))(
            qpi, jnp.broadcast_to(kp, (B, ki.shape[1])))
        return _attend(qi, ki, vi, bias)

    out = jax.lax.map(body, (qc, qpc, starts))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nchunk * Q_CHUNK, H, hd)
    return out[:, :S]


# ---------------------------------------------------------------------------
# MLPs


def swiglu_init(key, d, dff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, dff), dtype=dtype),
        "w_up": dense_init(k2, (d, dff), dtype=dtype),
        "w_down": dense_init(k3, (dff, d), dtype=dtype),
    }


def swiglu(params, x, ctx: Optional[ShardingCtx] = None, act=jax.nn.silu):
    h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    # TP/hybrid: d_ff column-parallel (Megatron).  Pure-FSDP archs:
    # token-parallel over the model axis instead — no full-sequence
    # activation ever materializes and the only collectives are the
    # per-layer FSDP weight gathers (§Perf iteration log).  NB: a plain
    # P(batch, None, None) constraint here forced a 4.6 GiB/layer
    # all-gather of the hidden — the original collective bottleneck.
    if ctx is not None and ctx.tp and not ctx.hybrid:
        h = constrain(h, ctx, "batch", None, "sp")
    else:
        h = constrain(h, ctx, "batch", "sp", None)
    return h @ params["w_down"]


def gelu_mlp_init(key, d, dff, dtype):
    k1, k2 = jax.random.split(key, 2)
    return {"w_in": dense_init(k1, (d, dff), dtype=dtype),
            "w_out": dense_init(k2, (dff, d), dtype=dtype)}


def gelu_mlp(params, x, ctx=None):
    h = jax.nn.gelu(x @ params["w_in"])
    h = constrain(h, ctx, "batch", None, "sp")
    return h @ params["w_out"]


# ---------------------------------------------------------------------------
# losses


def softmax_cross_entropy(logits, targets, mask=None, label_smoothing=0.0):
    """logits [..., C] f32; targets int [...]. Returns mean over mask.

    The true-class logit is extracted with an iota-mask reduction instead
    of take_along_axis: a gather along the vocab dim would force GSPMD to
    all-gather vocab-sharded logits (37 GiB/device for qwen2 train_4k),
    while the masked reduction partitions cleanly."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
              == targets[..., None])
    true_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss = logz - true_logit
    if label_smoothing:
        loss = (1 - label_smoothing) * loss + label_smoothing * (
            logz - logits.mean(axis=-1))
    if mask is None:
        return loss.mean()
    mask = mask.astype(jnp.float32)
    return (loss * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def softmax_cross_entropy_sums(logits, targets, mask=None,
                               label_smoothing=0.0):
    """(weighted loss sum, weight sum) — the chunked-CE building block."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
              == targets[..., None])
    true_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss = logz - true_logit
    if label_smoothing:
        loss = (1 - label_smoothing) * loss + label_smoothing * (
            logz - logits.mean(axis=-1))
    if mask is None:
        mask = jnp.ones(loss.shape, jnp.float32)
    mask = mask.astype(jnp.float32)
    return (loss * mask).sum(), mask.sum()
