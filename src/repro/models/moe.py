"""Mixture-of-Experts FFN with TPU-native expert parallelism.

Design (see DESIGN.md §3): activations are replicated across the tensor-
parallel ('model') axis after attention, so experts are sharded over
'model' and each shard computes *its* experts for the full local token set,
then partial outputs are psum'd — exactly the collective pattern of a
row-parallel matmul, with zero all-to-all.  Dispatch is sort-based
(capacity-bounded gather), never a one-hot einsum, so HLO FLOPs stay equal
to real expert FLOPs (important for the roofline's MODEL_FLOPS/HLO_FLOPS
ratio).

Without a mesh (ctx=None) the same dispatch code runs with all experts
local — this is the smoke-test and single-device FL path, and also the
oracle for the shard_map path in tests.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import ShardingCtx, constrain
from repro.models.layers import dense_init, swiglu, swiglu_init


def moe_params_init(key, cfg: ModelConfig, dtype):
    d, dff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d, E), scale=0.02, dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, dff), scale=1 / math.sqrt(d),
                             dtype=dtype),
        "w_up": dense_init(ks[2], (E, d, dff), scale=1 / math.sqrt(d),
                           dtype=dtype),
        "w_down": dense_init(ks[3], (E, dff, d), scale=1 / math.sqrt(dff),
                             dtype=dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = swiglu_init(ks[4], d,
                                  cfg.num_shared_experts * dff, dtype)
    if cfg.moe_dense_ff:
        p["dense_residual"] = swiglu_init(ks[5], d, cfg.moe_dense_ff, dtype)
    return p


def _capacity(tokens: int, k: int, num_experts: int, factor: float) -> int:
    """Capacity per expert.  Floored at min(tokens, 32) so small-token
    calls (decode: one token per sequence) are exactly drop-free — decode
    must match the prefill/train computation bit-for-bit."""
    cap = int(math.ceil(tokens * k / num_experts * factor))
    return max(cap, min(tokens, 32))


def top_k_routing(router_logits, k):
    """router_logits [T,E] -> (weights [T,k] f32, experts [T,k] i32, probs)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    weights, experts = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, experts, probs


def _positions_in_expert(flat_experts, num_experts):
    """For each routed assignment, its arrival rank within its expert.

    flat_experts [N] int32 in [0,E). Returns (pos_in_expert [N],
    group_sizes [E]).  Pure jnp: sort-based, O(N log N), static shapes."""
    n = flat_experts.shape[0]
    order = jnp.argsort(flat_experts, stable=True)
    sorted_e = flat_experts[order]
    group_sizes = jnp.bincount(flat_experts, length=num_experts)
    group_start = jnp.cumsum(group_sizes) - group_sizes          # [E]
    pos_sorted = jnp.arange(n) - group_start[sorted_e]
    pos = jnp.zeros((n,), dtype=jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    return pos, group_sizes, order, group_start


def _expert_ffn(params, x_buf):
    """x_buf [E, cap, d] -> [E, cap, d] via per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_buf, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", x_buf, params["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


def _moe_local(params, cfg: ModelConfig, x, expert_lo, num_local_experts,
               capacity):
    """Dispatch + expert compute for experts [lo, lo+n_local) on tokens x.

    x [T, d].  Returns partial output [T, d] (sum over local experts only)
    and the (local) load-balance stats."""
    T, d = x.shape
    k = cfg.experts_per_token
    E = cfg.num_experts

    # bf16 matmul with f32 accumulation: an explicit x.astype(f32) would
    # materialize a 1 GiB f32 copy of the token tensor per layer
    logits = jax.lax.dot_general(
        x, params["router"].astype(x.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    weights, experts, probs = top_k_routing(logits, k)          # [T,k]

    flat_e = experts.reshape(-1)                                # [N=T*k]
    flat_w = weights.reshape(-1)
    local_e = flat_e - expert_lo
    is_local = (local_e >= 0) & (local_e < num_local_experts)
    # non-local assignments go to an extra scratch bin so they never
    # pollute arrival ranks of real experts
    bins = jnp.where(is_local, local_e, num_local_experts)
    flat_w = jnp.where(is_local, flat_w, 0.0)

    pos, _, _, _ = _positions_in_expert(bins, num_local_experts + 1)
    fits = is_local & (pos < capacity)

    # gather-based dispatch: source token index for each (expert, slot).
    # Out-of-capacity / non-local assignments get an out-of-bounds slot and
    # are dropped by the scatter.
    token_idx = jnp.arange(T * k, dtype=jnp.int32) // k
    n_slots = num_local_experts * capacity
    local_e_safe = jnp.where(is_local, local_e, 0)
    slot_flat = jnp.where(fits, local_e_safe * capacity + pos, n_slots)
    src = jnp.full((n_slots,), T, dtype=jnp.int32)
    src = src.at[slot_flat].set(token_idx, mode="drop")
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    x_buf = x_pad[src].reshape(num_local_experts, capacity, d)

    y_buf = _expert_ffn(params, x_buf)                          # [E_l,cap,d]

    # combine: one gather per top-k slot, accumulated — never materializes
    # the [T*k, d] tensor (4.3 GiB for qwen3 train_4k, + f32 cotangent)
    y_flat = y_buf.reshape(num_local_experts * capacity, d)
    slot_2d = slot_flat.reshape(T, k)
    w_2d = (flat_w * fits.astype(jnp.float32)).reshape(T, k).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype)
    for j in range(k):
        idx = jnp.clip(slot_2d[:, j], 0, n_slots - 1)
        y = y + y_flat[idx] * w_2d[:, j, None]

    # load-balance aux stats (switch-style), computed over ALL experts
    me = probs.mean(axis=0)                                      # [E]
    ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * k)
    return y, me, ce


def moe_ffn(params, cfg: ModelConfig, x, ctx: Optional[ShardingCtx] = None):
    """x [B,S,d] -> (y [B,S,d], aux_loss scalar f32)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(B * S, d)

    n_model = 1
    if ctx is not None and ctx.model_axis is not None and ctx.mesh is not None:
        n_model = ctx.mesh.shape[ctx.model_axis]
    if E % max(n_model, 1) != 0:
        n_model = 1  # fall back to replicated experts for odd reductions

    if n_model == 1:
        capacity = _capacity(B * S, k, E, cfg.capacity_factor)
        y, me, ce = _moe_local(params, cfg, xt, 0, E, capacity)
    else:
        from jax.sharding import PartitionSpec as P
        mesh = ctx.mesh
        batch_axes = ctx.batch_axes
        n_batch = ctx.axis_size(batch_axes)
        t_local = B * S // n_batch
        capacity = _capacity(t_local, k, E, cfg.capacity_factor)
        e_local = E // n_model
        maxis = ctx.model_axis

        fsdp_axes = tuple(ctx.fsdp_axes)

        def shard_fn(xt_l, router, w_gate, w_up, w_down):
            midx = jax.lax.axis_index(maxis)
            # (an S-sharded boundary with in-shard all_gather/psum_scatter
            # was tried and REFUTED: 5x the bytes term — §Perf pair 3)
            # FSDP: expert weights are sharded over fsdp axes on a non-E
            # dim; gather per use (per-layer all-gather = FSDP semantics)
            def gather(w, dim):
                for ax in fsdp_axes:
                    w = jax.lax.all_gather(w, ax, axis=dim, tiled=True)
                return w
            w = {
                "router": router,
                "w_gate": gather(w_gate, 1),
                "w_up": gather(w_up, 1),
                "w_down": gather(w_down, 2),
            }
            y, me, ce = _moe_local(w, cfg, xt_l, midx * e_local, e_local,
                                   capacity)
            y = jax.lax.psum(y, maxis)
            me = jax.lax.pmean(me, batch_axes)
            ce = jax.lax.pmean(ce, batch_axes)
            return y, me, ce

        spec_tok = P(batch_axes, None)
        fsdp = fsdp_axes if fsdp_axes else None
        y, me, ce = jax.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(spec_tok, P(None, None), P(maxis, fsdp, None),
                      P(maxis, fsdp, None), P(maxis, None, fsdp)),
            out_specs=(spec_tok, P(None), P(None)),
            check_vma=False,
        )(xt, params["router"], params["w_gate"], params["w_up"],
          params["w_down"])

    aux = cfg.router_aux_loss * E * jnp.sum(me * ce)
    y = y.reshape(B, S, d)

    if "shared" in params:
        y = y + swiglu(params["shared"], x, ctx)
    if "dense_residual" in params:
        y = y + swiglu(params["dense_residual"], x, ctx)
    return y, aux
