"""Span-based tracer: monotonic-clock timings with nesting.

``Tracer.span(name)`` is a context manager; ``Tracer.trace(name)`` the
decorator form.  Finished spans land in two places: their duration is
observed into the registry histogram ``span.<name>`` (so percentiles
accumulate across the run), and a ``SpanRecord`` is appended to the
per-round buffer that ``drain()`` empties — the trainers drain once per
round to attach a ``phases`` breakdown to the round record.

Disabled tracers return the module-level ``NULL_SPAN`` singleton whose
``__enter__``/``__exit__`` do nothing: the cost of an off span is one
attribute check, no allocation, no clock read.
"""
from __future__ import annotations

import dataclasses
import functools
from time import perf_counter
from typing import Dict, List, Optional


@dataclasses.dataclass
class SpanRecord:
    name: str
    depth: int          # 0 = root; children appear before their parent
    seconds: float
    tags: Optional[Dict] = None


class _NullSpan:
    """Shared no-op span (disabled tracer)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "tags", "t0")

    def __init__(self, tracer: "Tracer", name: str, tags: Optional[Dict]):
        self._tracer = tracer
        self.name = name
        self.tags = tags

    def __enter__(self):
        self._tracer._depth += 1
        self.t0 = perf_counter()
        return self

    def __exit__(self, *exc):
        seconds = perf_counter() - self.t0
        tr = self._tracer
        tr._depth -= 1
        tr.records.append(SpanRecord(self.name, tr._depth, seconds,
                                     self.tags))
        tr.registry.histogram("span." + self.name).observe(seconds)
        return False


class Tracer:
    __slots__ = ("enabled", "registry", "records", "_depth")

    def __init__(self, registry, enabled: bool = True):
        self.enabled = enabled
        self.registry = registry
        self.records: List[SpanRecord] = []
        self._depth = 0

    def span(self, name: str, **tags):
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, tags or None)

    def trace(self, name: str):
        """Decorator form — the enabled check happens per call, so a
        function decorated while tracing is off becomes live the moment
        the tracer is enabled."""
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                if not self.enabled:
                    return fn(*args, **kwargs)
                with self.span(name):
                    return fn(*args, **kwargs)
            return wrapper
        return deco

    def drain(self) -> List[SpanRecord]:
        """Return and clear the span buffer (per-round flush)."""
        out, self.records = self.records, []
        return out
