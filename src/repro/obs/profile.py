"""``jax.profiler`` integration: capture a device trace of N rounds.

``profile_rounds(trainer, n, outdir)`` works for both
``FederatedTrainer`` and ``MultiCellTrainer`` (anything with
``run_round(j)`` and a ``history`` list): it runs ``warmup`` rounds
outside the trace so steady-state programs are what gets profiled, then
records ``n`` rounds under ``jax.profiler.trace``.  The output
directory can be opened with TensorBoard's profile plugin or Perfetto.
"""
from __future__ import annotations

import os
from typing import Union


def profile_rounds(trainer, n: int, outdir: Union[str, os.PathLike],
                   warmup: int = 1) -> str:
    """Capture a ``jax.profiler`` trace of ``n`` steady-state rounds.

    Rounds continue from the trainer's current position
    (``len(trainer.history)``), so profiling composes with a run already
    in flight.  Returns the trace directory."""
    import jax

    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    outdir = str(outdir)
    os.makedirs(outdir, exist_ok=True)
    j = len(trainer.history)
    for _ in range(warmup):
        trainer.run_round(j)
        j += 1
    with jax.profiler.trace(outdir):
        for _ in range(n):
            trainer.run_round(j)
            j += 1
    return outdir
