"""repro.obs — round-phase tracing, metrics registry, sinks, profiling.

The observability substrate for the FL engine:

  * ``Obs`` / ``from_config`` — the facade trainers hold (span tracer +
    metrics registry + sink fan-out); ``DISABLED`` is the shared no-op
    used whenever ``FLConfig.obs.enabled`` is False.
  * ``Registry`` / ``Counter`` / ``Gauge`` / ``Histogram`` — host-side
    metrics with fixed-bucket percentiles; never a device sync.
  * ``MemorySink`` / ``JSONLSink`` / ``ConsoleSink`` — per-round record
    sinks; ``read_jsonl`` / ``format_summary`` for consumers.
  * ``profile_rounds`` — ``jax.profiler`` trace of N steady rounds.

Span names emitted by the trainers: ``round`` (whole round), and its
phases ``prep`` / ``core`` / ``schedule`` / ``upload`` / ``finalize``,
plus ``solve_many.<backend>`` inside scheduling.  Metric names are
documented in ROADMAP.md's Observability section.
"""
from repro.obs.config import ObsConfig  # noqa: F401
from repro.obs.core import (DEFAULT, DISABLED, Obs,  # noqa: F401
                            enable_default, from_config)
from repro.obs.metrics import (COUNT_BUCKETS, TIME_BUCKETS,  # noqa: F401
                               Counter, Gauge, Histogram, Registry)
from repro.obs.profile import profile_rounds  # noqa: F401
from repro.obs.sinks import (ConsoleSink, JSONLSink,  # noqa: F401
                             MemorySink, dumps_record, format_summary,
                             read_jsonl)
from repro.obs.tracing import NULL_SPAN, SpanRecord, Tracer  # noqa: F401
