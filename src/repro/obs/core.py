"""The ``Obs`` facade: one tracer + one registry + the sink fan-out.

Trainers hold an ``Obs`` built by ``from_config(FLConfig.obs)``.  With
observability off (the default) that is the shared ``DISABLED``
singleton: ``span()`` returns the no-op null span, ``instrument_jit``
returns the callable unchanged, and every emit helper is guarded by
``if obs.enabled`` at the call site — the fault-free round is
bitwise-identical to the uninstrumented trainer and pays no measurable
per-round cost.

``DEFAULT`` is the process-wide facade used by library code that has no
trainer handle (e.g. ``core.scheduling.solve_many`` when called without
``obs=``).  It starts disabled; ``enable_default()`` arms it.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional

from repro.obs.config import ObsConfig
from repro.obs.metrics import Registry
from repro.obs.sinks import ConsoleSink, JSONLSink, MemorySink
from repro.obs.tracing import Tracer


class Obs:
    def __init__(self, enabled: bool = True,
                 registry: Optional[Registry] = None, sinks=()):
        self.enabled = enabled
        self.metrics = registry if registry is not None else Registry()
        self.tracer = Tracer(self.metrics, enabled=enabled)
        self.sinks = list(sinks)

    # ------------------------------------------------------------------
    # tracing
    def span(self, name: str, **tags):
        return self.tracer.span(name, **tags)

    def trace(self, name: str):
        return self.tracer.trace(name)

    # ------------------------------------------------------------------
    # XLA compile tracking
    def instrument_jit(self, name: str, fn):
        """Wrap a jitted callable to count compiles and compile seconds.

        A call that grows the function's executable cache is counted as
        a compile and its whole wall time attributed to
        ``xla.compile_seconds_total`` (dispatch is asynchronous, so on a
        compile call the trace+lower+compile time dominates; steady
        calls add nothing).  When disabled, returns ``fn`` unchanged —
        zero indirection on the hot path."""
        if not self.enabled:
            return fn
        cache_size = getattr(fn, "_cache_size", None)
        reg = self.metrics

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            n0 = cache_size() if cache_size is not None else -1
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            reg.counter(f"xla.calls.{name}").inc()
            if cache_size is not None and cache_size() > n0:
                dt = time.perf_counter() - t0
                reg.counter("xla.compiles_total").inc()
                reg.counter(f"xla.compiles.{name}").inc()
                reg.counter("xla.compile_seconds_total").inc(dt)
                reg.counter(f"xla.compile_seconds.{name}").inc(dt)
            return out

        wrapped.__wrapped__ = fn
        return wrapped

    # ------------------------------------------------------------------
    # sinks
    def emit(self, record: Dict) -> None:
        for sink in self.sinks:
            sink.emit(record)

    def round_record(self, record: Dict) -> Dict:
        """Attach the drained span breakdown to ``record`` and emit it.

        ``phases`` maps each depth-1 span name to its summed seconds;
        ``round_s`` is the enclosing depth-0 ``round`` span's duration,
        so consumers can check that the phases cover the round."""
        phases: Dict[str, float] = {}
        round_s = None
        for s in self.tracer.drain():
            if s.depth == 0 and s.name == "round":
                round_s = s.seconds
            elif s.depth == 1:
                phases[s.name] = phases.get(s.name, 0.0) + s.seconds
        out = dict(record)
        out.setdefault("kind", "round")
        if phases:
            out["phases"] = phases
        if round_s is not None:
            out["round_s"] = round_s
        self.emit(out)
        return out

    def records(self) -> List[Dict]:
        """Records held by the first memory sink ([] if none)."""
        for sink in self.sinks:
            if isinstance(sink, MemorySink):
                return sink.records()
        return []

    def close(self) -> None:
        for sink in self.sinks:
            sink.close()


# Shared no-op facade for every obs-disabled trainer.  Never written to
# (all writers guard on ``enabled``), so sharing is safe.
DISABLED = Obs(enabled=False)

# Process-wide facade for code without a trainer handle.  Disabled
# until ``enable_default()``.
DEFAULT = Obs(enabled=False)


def enable_default(sinks=()) -> Obs:
    """Arm the process-wide ``DEFAULT`` facade (idempotent)."""
    DEFAULT.enabled = True
    DEFAULT.tracer.enabled = True
    for s in sinks:
        DEFAULT.sinks.append(s)
    return DEFAULT


def from_config(cfg: Optional[ObsConfig]) -> Obs:
    """Build a facade from ``FLConfig.obs`` (the shared ``DISABLED``
    singleton when off — no per-trainer state at all)."""
    if cfg is None or not cfg.enabled:
        return DISABLED
    sinks = []
    if cfg.ring_size:
        sinks.append(MemorySink(cfg.ring_size))
    if cfg.jsonl_path is not None:
        sinks.append(JSONLSink(cfg.jsonl_path))
    if cfg.console:
        sinks.append(ConsoleSink())
    return Obs(enabled=True, sinks=sinks)
