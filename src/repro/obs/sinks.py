"""Pluggable round-record sinks + the human-readable summary.

A sink is anything with ``emit(record: dict)`` and ``close()``.  The
trainers emit one record per round (after the round's device work is
done — no extra host syncs on the hot path):

  * ``MemorySink``   — fixed-capacity ring buffer of the last N records
  * ``JSONLSink``    — one JSON object per line, flushed per emit
  * ``ConsoleSink``  — compact one-line digest per record

``format_summary(registry)`` renders the end-of-run table: per-span
p50/p95/mean/total from the ``span.*`` histograms plus every counter
and gauge (failure-cause totals, host syncs, compile counts, ...).
"""
from __future__ import annotations

import json
import math
from collections import deque
from typing import Dict, List

import numpy as np

from repro.obs.metrics import Registry


def _json_default(o):
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.bool_):
        return bool(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


def dumps_record(record: Dict) -> str:
    """One record as a compact JSON line (numpy scalars coerced)."""
    return json.dumps(record, default=_json_default,
                      separators=(",", ":"))


class MemorySink:
    """Ring buffer of the last ``capacity`` records."""

    def __init__(self, capacity: int = 1024):
        if capacity <= 0:
            raise ValueError(f"capacity must be > 0, got {capacity}")
        self._buf = deque(maxlen=capacity)

    def emit(self, record: Dict) -> None:
        self._buf.append(record)

    def records(self) -> List[Dict]:
        return list(self._buf)

    def close(self) -> None:
        pass


class JSONLSink:
    """One JSON object per line; flushed after every emit so a crashed
    run keeps all completed rounds."""

    def __init__(self, path: str, mode: str = "w"):
        self.path = str(path)
        self._f = open(self.path, mode)

    def emit(self, record: Dict) -> None:
        self._f.write(dumps_record(record) + "\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def read_jsonl(path: str) -> List[Dict]:
    """Load every record of a JSONL metrics file."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


class ConsoleSink:
    """Compact per-record console line (round digests, not raw JSON)."""

    def emit(self, record: Dict) -> None:
        kind = record.get("kind", "record")
        j = record.get("round", "?")
        bits = [f"[obs] {kind} {j}"]
        for k in ("num_scheduled", "num_uploaded", "num_failed",
                  "host_syncs", "cells"):
            if k in record:
                bits.append(f"{k}={record[k]}")
        if "round_s" in record:
            bits.append(f"round={record['round_s'] * 1e3:.1f}ms")
        phases = record.get("phases")
        if phases:
            bits.append(" ".join(f"{k}={v * 1e3:.1f}ms"
                                 for k, v in phases.items()))
        print(" ".join(bits))

    def close(self) -> None:
        pass


def _fmt_s(v: float) -> str:
    if v != v:          # nan
        return "    -"
    if v >= 1.0:
        return f"{v:7.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:6.1f}ms"
    return f"{v * 1e6:6.1f}us"


def format_summary(registry: Registry) -> str:
    """End-of-run console summary: span percentiles + counters/gauges."""
    lines = []
    hists = {k[len("span."):]: h for k, h in registry.histograms.items()
             if k.startswith("span.") and h.count}
    if hists:
        lines.append("-- span timings --")
        lines.append(f"{'span':<24}{'count':>7}{'p50':>10}{'p95':>10}"
                     f"{'mean':>10}{'total':>10}")
        for name in sorted(hists):
            h = hists[name]
            lines.append(f"{name:<24}{h.count:>7}"
                         f"{_fmt_s(h.percentile(0.5)):>10}"
                         f"{_fmt_s(h.percentile(0.95)):>10}"
                         f"{_fmt_s(h.mean):>10}{_fmt_s(h.sum):>10}")
    other = {k: h for k, h in registry.histograms.items()
             if not k.startswith("span.") and h.count}
    if other:
        lines.append("-- histograms --")
        for name in sorted(other):
            h = other[name]
            lines.append(f"{name:<32} count={h.count} mean={h.mean:.3g} "
                         f"p50={h.percentile(0.5):.3g} "
                         f"p95={h.percentile(0.95):.3g}")
    counters = registry.counters
    if counters:
        lines.append("-- counters --")
        for name in sorted(counters):
            v = counters[name].value
            lines.append(f"{name:<32} "
                         f"{int(v) if v == int(v) else round(v, 6)}")
    gauges = {k: g for k, g in registry.gauges.items()
              if not math.isnan(g.value)}
    if gauges:
        lines.append("-- gauges --")
        for name in sorted(gauges):
            lines.append(f"{name:<32} {gauges[name].value:.6g}")
    return "\n".join(lines)
