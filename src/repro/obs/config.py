"""Observability knobs (``FLConfig.obs``).

Inert by default: with ``enabled=False`` the trainers hold the shared
``repro.obs.DISABLED`` facade, every span is the no-op singleton, no
metric is written and no sink exists — a fault-free round is
bitwise-identical to a trainer built before the observability layer
existed and pays no measurable per-round cost.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    # Master switch.  False (default) = the no-op ``DISABLED`` facade.
    enabled: bool = False
    # Stream one JSON record per round (phase timings, telemetry) to
    # this path via the JSONL sink.  None = no file sink.
    jsonl_path: Optional[str] = None
    # Keep the last N round records in an in-memory ring buffer
    # (``Obs.records()``).  0 = no memory sink.
    ring_size: int = 1024
    # Print a one-line console digest of every round record.
    console: bool = False

    def __post_init__(self):
        if self.ring_size < 0:
            raise ValueError(f"ring_size must be >= 0, got {self.ring_size}")
        if self.jsonl_path is not None and not str(self.jsonl_path):
            raise ValueError("jsonl_path must be a non-empty path or None")
