"""Process-wide metrics registry: counters, gauges, fixed-bucket
histograms.

All metrics are plain host-side Python objects — observing one is a
dict lookup plus a few float ops, never a device sync, so the round hot
path can mirror its telemetry here without breaking the trainers'
host-sync contract.

Percentiles are estimated from fixed buckets (log-spaced 1-2.5-5 per
decade by default): ``percentile(q)`` returns the upper edge of the
bucket holding the q-quantile rank, clamped to the observed [min, max].
The estimate is exact to within one bucket granule (<= 2.5x), which is
what regression gating on phase times needs — not a t-digest.
"""
from __future__ import annotations

import math
from bisect import bisect_left
from typing import Dict, Optional, Sequence

# log-spaced seconds: 1 us .. 500 s (1 / 2.5 / 5 per decade)
TIME_BUCKETS = tuple(round(10.0 ** e * m, 12)
                     for e in range(-6, 3) for m in (1.0, 2.5, 5.0))
# small-integer counts: scheduler iterations, device tallies, ...
COUNT_BUCKETS = (1, 2, 5, 10, 25, 50, 100, 250, 500, 1000, 2500, 5000,
                 10_000, 100_000, 1_000_000)


class Counter:
    """Monotone accumulator (float increments allowed — e.g. seconds)."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        self.value += amount

    def reset(self) -> None:
        self.value = 0.0


class Gauge:
    """Last-write-wins scalar."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = math.nan

    def set(self, value: float) -> None:
        self.value = float(value)

    def reset(self) -> None:
        self.value = math.nan


class Histogram:
    """Fixed-bucket histogram with running count/sum/min/max."""
    __slots__ = ("name", "buckets", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str, buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.buckets = tuple(sorted(buckets)) if buckets else TIME_BUCKETS
        self.counts = [0] * (len(self.buckets) + 1)
        self.reset()

    def reset(self) -> None:
        for i in range(len(self.counts)):
            self.counts[i] = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        value = float(value)
        self.counts[bisect_left(self.buckets, value)] += 1
        self.count += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def percentile(self, q: float) -> float:
        """Upper bucket edge at quantile ``q`` in [0, 1], clamped to the
        observed [min, max] (exact for q=0/q=1)."""
        if not self.count:
            return math.nan
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if q == 0.0:
            return self.min
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target and c:
                edge = (self.buckets[i] if i < len(self.buckets)
                        else self.max)
                return min(max(edge, self.min), self.max)
        return self.max


class Registry:
    """Name -> metric, get-or-create.  One registry per ``Obs`` facade;
    ``repro.obs.DEFAULT`` carries the process-wide instance."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, buckets)
        return h

    @property
    def counters(self) -> Dict[str, Counter]:
        return dict(self._counters)

    @property
    def gauges(self) -> Dict[str, Gauge]:
        return dict(self._gauges)

    @property
    def histograms(self) -> Dict[str, Histogram]:
        return dict(self._histograms)

    def reset(self) -> None:
        """Zero every metric in place (object identities survive, so
        held references stay valid — e.g. steady-state benchmarking
        resets after warmup)."""
        for m in (*self._counters.values(), *self._gauges.values(),
                  *self._histograms.values()):
            m.reset()

    def snapshot(self) -> Dict:
        """Plain-data view of every metric (JSON-serializable)."""
        return {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: g.value for k, g in self._gauges.items()},
            "histograms": {
                k: {"count": h.count, "sum": h.sum, "mean": h.mean,
                    "min": h.min if h.count else math.nan,
                    "max": h.max if h.count else math.nan,
                    "p50": h.percentile(0.5), "p95": h.percentile(0.95),
                    "p99": h.percentile(0.99)}
                for k, h in self._histograms.items()},
        }
