"""3GPP TR 38.901 UMi-Street-Canyon uplink channel (paper Section VI-A,
Table I).

Path loss (f in GHz, d3d in m):
    PL_LOS  = 32.4 + 21.0  log10(d3d) + 20 log10(f)
    PL_NLOS = 32.4 + 31.9  log10(d3d) + 20 log10(f)
LOS probability:
    Pr_LOS = 18/d2d + exp(-d2d/36) (1 - 18/d2d)     (d2d > 18 m, else 1)
Shadow fading: lognormal, std 4 dB (LOS) / 8.2 dB (NLOS).
Fast fading is not modeled (paper: average rate over the upload deadline).

Default parameters are the paper's Table I.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChannelParams:
    cell_radius_m: float = 250.0
    carrier_ghz: float = 3.5
    total_bandwidth_hz: float = 20e6
    tx_power_dbm: float = 23.0
    device_height_m: float = 1.5
    bs_height_m: float = 10.0
    noise_psd_dbm_hz: float = -174.0
    noise_figure_db: float = 6.0
    shadow_std_los_db: float = 4.0
    shadow_std_nlos_db: float = 8.2

    @property
    def tx_power_w(self) -> float:
        return 10 ** (self.tx_power_dbm / 10.0) * 1e-3

    @property
    def noise_psd_w(self) -> float:
        # receiver noise figure folds into the effective noise density
        return 10 ** ((self.noise_psd_dbm_hz + self.noise_figure_db) / 10.0) \
            * 1e-3


def los_probability(d2d: np.ndarray) -> np.ndarray:
    d2d = np.maximum(np.asarray(d2d, dtype=np.float64), 1e-3)
    p = 18.0 / d2d + np.exp(-d2d / 36.0) * (1.0 - 18.0 / d2d)
    return np.where(d2d <= 18.0, 1.0, np.minimum(p, 1.0))


def path_loss_db(d3d: np.ndarray, f_ghz: float, los: np.ndarray) -> np.ndarray:
    d3d = np.maximum(np.asarray(d3d, dtype=np.float64), 1.0)
    pl_los = 32.4 + 21.0 * np.log10(d3d) + 20.0 * np.log10(f_ghz)
    pl_nlos = 32.4 + 31.9 * np.log10(d3d) + 20.0 * np.log10(f_ghz)
    return np.where(los, pl_los, pl_nlos)


@dataclasses.dataclass
class CellState:
    """Positions + per-round channel realisation for V devices."""
    params: ChannelParams
    positions: np.ndarray        # [V, 2]
    d2d: np.ndarray              # [V]
    d3d: np.ndarray              # [V]

    def invariants(self):
        """Round-invariant channel quantities (cached): the per-device
        LOS probability and both path-loss branches.  Positions are
        fixed for a cell's lifetime, so only the LOS coin flip and the
        shadow draw vary per round."""
        inv = getattr(self, "_invariants", None)
        if inv is None:
            p = self.params
            inv = (los_probability(self.d2d),
                   path_loss_db(self.d3d, p.carrier_ghz,
                                np.ones(len(self.d3d), bool)),
                   path_loss_db(self.d3d, p.carrier_ghz,
                                np.zeros(len(self.d3d), bool)))
            self._invariants = inv
        return inv

    def draw_shadowed_loss_db(self, rng: np.random.Generator) -> np.ndarray:
        """One round's raw RNG pass: LOS coin flip + shadow draw, folded
        with the cached path loss into PL + X_shadow (dB).  Kept separate
        from the dB->linear conversion so a multi-cell driver can batch
        that last pass over a stacked [C, V] array."""
        p_los, pl_los, pl_nlos = self.invariants()
        p = self.params
        los = rng.random(len(self.d2d)) < p_los
        pl = np.where(los, pl_los, pl_nlos)
        shadow_std = np.where(los, p.shadow_std_los_db, p.shadow_std_nlos_db)
        shadow = rng.normal(0.0, shadow_std)
        return pl + shadow

    def draw_gains(self, rng: np.random.Generator) -> np.ndarray:
        """Average channel gain H_v for one round (linear, power)."""
        return 10 ** (-self.draw_shadowed_loss_db(rng) / 10.0)

    def received_power(self, gains: np.ndarray) -> np.ndarray:
        """S * H_v in W — feeds core.bandwidth.min_bandwidth."""
        return self.params.tx_power_w * gains


def draw_gains_batch(cells: Sequence[CellState],
                     rngs: Sequence[np.random.Generator]) -> np.ndarray:
    """Channel gains for C cells in one vectorized pass: [C, V].

    Each cell's raw draws (LOS coin flip, shadow fade) still come from
    its own generator in the exact order ``draw_gains`` consumes them —
    a cell's stream is bitwise-identical to a standalone draw — but the
    dB->linear conversion runs once over the stacked [C, V] array
    instead of C times over [V] slices (elementwise, so the values are
    unchanged)."""
    loss_db = np.stack([cell.draw_shadowed_loss_db(rng)
                        for cell, rng in zip(cells, rngs)])
    return 10 ** (-loss_db / 10.0)


def received_power_batch(cells: Sequence[CellState],
                         gains: np.ndarray) -> np.ndarray:
    """S * H for a [C, V] gain stack (per-cell tx power broadcast)."""
    tx = np.array([cell.params.tx_power_w for cell in cells])
    return tx[:, None] * np.asarray(gains)


def apply_shadow_db(gains: np.ndarray, shadow_db: np.ndarray) -> np.ndarray:
    """Fold an extra shadow-fading realisation (dB, positive = deeper
    fade) into linear power gains.

    The scheduler measures H_v once per round; a second draw at upload
    time models the shadowing decorrelating between measurement and
    transmission — the fault layer's upload-outage channel."""
    return np.asarray(gains, dtype=np.float64) \
        * 10.0 ** (-np.asarray(shadow_db, dtype=np.float64) / 10.0)


def make_cell(num_devices: int, rng: np.random.Generator,
              params: ChannelParams = ChannelParams()) -> CellState:
    """Devices uniform in the disc of the cell radius."""
    r = params.cell_radius_m * np.sqrt(rng.random(num_devices))
    theta = rng.random(num_devices) * 2 * np.pi
    pos = np.stack([r * np.cos(theta), r * np.sin(theta)], axis=1)
    d2d = np.linalg.norm(pos, axis=1)
    dh = params.bs_height_m - params.device_height_m
    d3d = np.sqrt(d2d ** 2 + dh ** 2)
    return CellState(params=params, positions=pos, d2d=d2d, d3d=d3d)
