from repro.wireless.channel import (  # noqa: F401
    CellState,
    ChannelParams,
    los_probability,
    make_cell,
    path_loss_db,
)
