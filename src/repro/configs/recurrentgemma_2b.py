"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000. Griffin: RG-LRU recurrent blocks + local attention at a
2:1 recurrent:attention pattern, window 2048. [arXiv:2402.19427]"""
from repro.configs.base import LOCAL_ATTN, RECURRENT, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=(RECURRENT, RECURRENT, LOCAL_ATTN),
    window_size=2048,
    lru_width=2560,
    conv1d_width=4,
    pos_embedding="rope",
    tie_embeddings=True,
)
