"""The paper's own CIFAR-10 CNN (Section VI-A) and ResNet18-GN (CIFAR-100).

The CNN: two conv blocks (2x conv3x3-32 + maxpool + dropout0.2,
2x conv3x3-64 + maxpool + dropout0.3), FC-120, softmax-10.
ResNet18 with every BatchNorm replaced by GroupNorm [50] to make FL on
heterogeneous data converge.

These use a separate config type because they are vision CNNs, not
sequence models; models/cnn.py consumes them.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    kind: str                 # "paper_cnn" | "resnet18_gn"
    num_classes: int
    image_size: int = 32
    channels: int = 3
    dropout: bool = True
    gn_groups: int = 8        # for resnet18_gn
    width: float = 1          # channel multiplier; may be fractional
    #   (micro benchmark variants round channels to >= 1)

    def reduced(self) -> "CNNConfig":
        return dataclasses.replace(self, name=self.name + "-reduced",
                                   image_size=16, dropout=False)


PAPER_CNN_CIFAR10 = CNNConfig(name="paper-cnn-cifar10", kind="paper_cnn",
                              num_classes=10)
RESNET18_GN_CIFAR100 = CNNConfig(name="resnet18-gn-cifar100",
                                 kind="resnet18_gn", num_classes=100)
