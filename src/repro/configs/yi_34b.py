"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
Llama-architecture GQA. [arXiv:2403.04652]"""
from repro.configs.base import GLOBAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    source="arXiv:2403.04652",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    block_pattern=(GLOBAL_ATTN,),
    rope_theta=5_000_000.0,
)
