"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) expert d_ff=4864
vocab=32000, MoE 128 experts top-2 PLUS a parallel dense-residual MLP
(dense-MoE hybrid). [hf:Snowflake/snowflake-arctic-base]

Assumption noted in DESIGN.md: the dense-residual intermediate size is set
to d_model (7168), matching Arctic's ~10B dense share across 35 layers.
"""
from repro.configs.base import GLOBAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    source="hf:Snowflake/snowflake-arctic-base",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    block_pattern=(GLOBAL_ATTN,),
    num_experts=128,
    experts_per_token=2,
    moe_dense_ff=7168,
    rope_theta=10_000.0,
)
