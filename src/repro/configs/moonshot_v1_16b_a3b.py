"""moonshot-v1-16b-a3b [dense w/ MoE FFN]: 48L d_model=2048 16H (GQA kv=16,
i.e. MHA) expert d_ff=1408 vocab=163840, MoE 64 experts top-6 with
DeepSeek-V3-style shared experts. [hf:moonshotai/Moonlight-16B-A3B]

Assumption noted in DESIGN.md: Moonlight uses 2 shared experts and a dense
first layer; we keep 2 shared experts and make every layer MoE (uniform
pattern keeps the scanned dry-run HLO small; parameter count deviates <1%).
"""
from repro.configs.base import GLOBAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    source="hf:moonshotai/Moonlight-16B-A3B",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    block_pattern=(GLOBAL_ATTN,),
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    rope_theta=50_000.0,
)
