"""Config registry: ``--arch <id>`` resolution for every assigned
architecture plus the paper's own models."""
from __future__ import annotations

from repro.configs.base import ModelConfig, TrainConfig  # noqa: F401
from repro.configs.paper_cnn import (  # noqa: F401
    PAPER_CNN_CIFAR10,
    RESNET18_GN_CIFAR100,
    CNNConfig,
)

from repro.configs.gemma3_27b import CONFIG as GEMMA3_27B
from repro.configs.moonshot_v1_16b_a3b import CONFIG as MOONSHOT_V1_16B_A3B
from repro.configs.rwkv6_3b import CONFIG as RWKV6_3B
from repro.configs.qwen2_7b import CONFIG as QWEN2_7B
from repro.configs.qwen3_moe_235b_a22b import CONFIG as QWEN3_MOE_235B_A22B
from repro.configs.yi_34b import CONFIG as YI_34B
from repro.configs.arctic_480b import CONFIG as ARCTIC_480B
from repro.configs.recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B
from repro.configs.musicgen_large import CONFIG as MUSICGEN_LARGE
from repro.configs.llama32_vision_11b import CONFIG as LLAMA32_VISION_11B

ARCHITECTURES = {
    cfg.name: cfg
    for cfg in [
        GEMMA3_27B,
        MOONSHOT_V1_16B_A3B,
        RWKV6_3B,
        QWEN2_7B,
        QWEN3_MOE_235B_A22B,
        YI_34B,
        ARCTIC_480B,
        RECURRENTGEMMA_2B,
        MUSICGEN_LARGE,
        LLAMA32_VISION_11B,
    ]
}

CNN_MODELS = {
    PAPER_CNN_CIFAR10.name: PAPER_CNN_CIFAR10,
    RESNET18_GN_CIFAR100.name: RESNET18_GN_CIFAR100,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHITECTURES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(ARCHITECTURES)}")
    cfg = ARCHITECTURES[arch]
    cfg.validate()
    return cfg


def list_archs():
    return sorted(ARCHITECTURES)
