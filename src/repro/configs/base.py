"""Model/config system for the repro framework.

A single frozen dataclass expresses every assigned architecture family:
dense GQA transformers, MoE (incl. shared-expert and dense-residual
variants), RWKV6 (attention-free), RG-LRU hybrids (Griffin/RecurrentGemma),
VLM cross-attention decoders and audio-token decoders.

Layer heterogeneity (sliding-window vs global attention, recurrent vs
attention, self vs cross attention) is expressed with ``block_pattern``: a
tuple of layer-kind strings that repeats with period ``len(block_pattern)``.
Layer ``l`` has kind ``block_pattern[l % len(block_pattern)]``.  The model
implementation scans over full pattern periods (stacked params) and unrolls
the remainder, which keeps HLO size (and therefore AOT compile time for the
512-device dry-run) small.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Layer kinds understood by models/transformer.py
GLOBAL_ATTN = "global"      # full causal attention
LOCAL_ATTN = "local"        # sliding-window causal attention
CROSS_ATTN = "cross"        # cross-attention to encoder states (VLM)
RECURRENT = "recurrent"     # RG-LRU block (Griffin)
RWKV = "rwkv"               # RWKV6 time-mix block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str                      # citation for the numbers below

    # trunk
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    block_pattern: Tuple[str, ...] = (GLOBAL_ATTN,)

    # attention details
    window_size: int = 0             # for LOCAL_ATTN layers
    qkv_bias: bool = False           # qwen2-style QKV bias
    qk_norm: bool = False            # gemma3-style RMSNorm on q,k
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0   # gemma3 uses a lower theta locally
    logit_softcap: float = 0.0           # final-logit soft capping (gemma)
    pos_embedding: str = "rope"          # rope | sinusoidal | none
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0      # moonlight/deepseek-style shared experts
    moe_dense_ff: int = 0            # arctic-style parallel dense-residual MLP
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01    # load-balance auxiliary loss weight

    # recurrent (RG-LRU / RWKV)
    lru_width: int = 0               # RG-LRU recurrence width (0 -> d_model)
    conv1d_width: int = 4            # temporal conv in recurrent blocks
    rwkv_head_dim: int = 64          # RWKV6 head size

    # multimodal
    num_encoder_tokens: int = 0      # image patches / audio frames (stub frontend)
    encoder_dim: int = 0             # frontend embedding dim (projected to d_model)

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True

    # cost-accounting mode (dry-run only): XLA's HloCostAnalysis counts
    # while-loop bodies ONCE, so scanned layers/chunks under-count by the
    # trip count.  With unroll_for_costing the periods and inner
    # seq-chunk loops become straight-line HLO; the dry-run compiles P=1
    # and P=2 period variants and linearly extrapolates exact totals.
    unroll_for_costing: bool = False

    # training head: number of label classes for FL classification tasks;
    # 0 means next-token prediction over vocab_size.
    num_label_classes: int = 0

    # ------------------------------------------------------------------
    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_full_periods(self) -> int:
        return self.num_layers // self.pattern_period

    @property
    def num_remainder_layers(self) -> int:
        return self.num_layers % self.pattern_period

    def layer_kind(self, layer_idx: int) -> str:
        return self.block_pattern[layer_idx % self.pattern_period]

    @property
    def attends_full_context(self) -> bool:
        """True if *every* token-mixing layer is full (global) attention.

        Used to decide long_500k eligibility: archs whose pattern contains
        only GLOBAL_ATTN / CROSS_ATTN layers have no sub-quadratic path.
        """
        kinds = set(self.block_pattern)
        return kinds <= {GLOBAL_ATTN, CROSS_ATTN}

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test variant of the same family: <=2 pattern periods,
        d_model<=256, <=4 experts, small vocab. Exercises every layer kind
        in the pattern while running a CPU forward/train step in <seconds."""
        period = self.pattern_period
        num_layers = min(self.num_layers, max(2, period))
        d_model = min(self.d_model, 256)
        head_dim = min(self.head_dim, 32) if self.head_dim else 0
        num_heads = min(self.num_heads, 4) if self.num_heads else 0
        num_kv = min(self.num_kv_heads, max(1, num_heads // 2)) if self.num_kv_heads else 0
        kw = dict(
            name=self.name + "-reduced",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=max(num_kv, min(1, num_kv)),
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            window_size=min(self.window_size, 16) if self.window_size else 0,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.experts_per_token else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_dense_ff=min(self.moe_dense_ff, 128) if self.moe_dense_ff else 0,
            lru_width=min(self.lru_width, 256) if self.lru_width else 0,
            num_encoder_tokens=min(self.num_encoder_tokens, 16) if self.num_encoder_tokens else 0,
            encoder_dim=min(self.encoder_dim, 128) if self.encoder_dim else 0,
            dtype="float32",
            param_dtype="float32",
            remat=False,
        )
        kw.update(overrides)
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        assert self.num_layers > 0 and self.d_model > 0
        if self.num_heads:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name
        if self.num_experts:
            assert 0 < self.experts_per_token <= self.num_experts, self.name
        for kind in self.block_pattern:
            assert kind in (GLOBAL_ATTN, LOCAL_ATTN, CROSS_ATTN, RECURRENT, RWKV)
        if LOCAL_ATTN in self.block_pattern:
            assert self.window_size > 0, self.name


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Hyper-parameters of one training/serving job (paper Table I defaults
    live in wireless/, not here)."""
    learning_rate: float = 0.1       # paper: eta = 0.1
    batch_size: int = 32             # paper: b = 32
    local_iters: int = 1             # paper: tau
    optimizer: str = "sgd"           # FedAvg local update is plain SGD (Eq. 1)
    momentum: float = 0.0
    weight_decay: float = 0.0
    seed: int = 0
    label_smoothing: float = 0.0
