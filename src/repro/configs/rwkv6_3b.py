"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
Finch: data-dependent per-channel decay. [arXiv:2404.05892]"""
from repro.configs.base import RWKV, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=2560,
    num_heads=40,            # d_model / rwkv_head_dim
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    block_pattern=(RWKV,),
    rwkv_head_dim=64,
    pos_embedding="none",
)
