"""musicgen-large [audio]: 48L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=2048 (EnCodec codebook). Decoder-only over EnCodec tokens; the
EnCodec conv frontend is a STUB — input_specs() provides precomputed frame
embeddings. [arXiv:2306.05284]"""
from repro.configs.base import GLOBAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    block_pattern=(GLOBAL_ATTN,),
    pos_embedding="sinusoidal",
    num_encoder_tokens=0,     # decoder-only; frame embeddings arrive as inputs
    encoder_dim=2048,         # EnCodec frame embedding dim (stub frontend)
)
