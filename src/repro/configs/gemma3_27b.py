"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt] (family card; 27B scaling per brief)."""
from repro.configs.base import GLOBAL_ATTN, LOCAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    block_pattern=(LOCAL_ATTN,) * 5 + (GLOBAL_ATTN,),
    window_size=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    logit_softcap=0.0,
    tie_embeddings=True,
)
