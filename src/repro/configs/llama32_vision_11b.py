"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 with gated cross-attention image layers every 5th layer.
The ViT vision encoder + projector is a STUB — input_specs() provides
precomputed patch embeddings. [hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.base import CROSS_ATTN, GLOBAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    block_pattern=(GLOBAL_ATTN,) * 4 + (CROSS_ATTN,),
    rope_theta=500_000.0,
    num_encoder_tokens=1601,   # 1 tile x (1600 patches + CLS) from the stub ViT
    encoder_dim=4096,          # already projected to d_model by the stub
)
