"""Production meshes (brief: 16x16 single-pod, 2x16x16 multi-pod).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import to get 512 placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for CPU tests (requires
    --xla_force_host_platform_device_count set accordingly)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
