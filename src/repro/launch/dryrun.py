import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (brief §MULTI-POD DRY-RUN).

For every (architecture x input shape) lower + compile the appropriate
step (train_step / prefill_step / serve_step) against the production mesh
(16x16 single-pod, 2x16x16 multi-pod) using ShapeDtypeStruct inputs only,
then record memory_analysis / cost_analysis / collective bytes for the
roofline (EXPERIMENTS.md §Dry-run, §Roofline).

Cost accounting: XLA's HloCostAnalysis counts while-loop bodies ONCE, so
the scanned-layer program under-reports FLOPs/bytes/collectives by the
scan trip count.  The dry-run therefore also compiles two small
*unrolled* variants (1 and 2 pattern periods, straight-line HLO) and
linearly extrapolates the exact totals:
    metric(P) = out_of_loop + P * per_period
Memory analysis always comes from the REAL (scanned) executable.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse       # noqa: E402
import dataclasses    # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402

from repro.configs import get_config, list_archs                 # noqa: E402
from repro.fl.distributed import (make_prefill_step,             # noqa: E402
                                  make_serve_step, make_train_step)
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.launch.shapes import SHAPES, applicable_shapes, CACHE_PAD  # noqa: E402
from repro.launch import shardings as SH                         # noqa: E402
from repro.roofline import (collective_bytes_from_hlo,           # noqa: E402
                            model_flops, roofline_terms)


def _compile(cfg, shape, mesh, multi_pod: bool, opt: bool = False):
    ctx = SH.make_ctx(cfg, mesh, shape, opt=opt)
    params_spec = SH.param_specs(cfg)
    params_sh = SH.param_shardings(params_spec, cfg, ctx)
    batch_spec = SH.input_specs(cfg, shape, federated=multi_pod
                                and shape.kind == "train")
    batch_sh = SH.batch_shardings(batch_spec, ctx)
    with mesh:
        if shape.kind == "train":
            step = make_train_step(cfg, ctx, federated=multi_pod)
            lowered = jax.jit(
                step, in_shardings=(params_sh, batch_sh),
                out_shardings=(params_sh, None),
            ).lower(params_spec, batch_spec)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, ctx, shape.seq_len + CACHE_PAD)
            cache_spec = SH.cache_specs(cfg, shape)
            cache_sh = SH.cache_shardings(cache_spec, cfg, ctx)
            lowered = jax.jit(
                step, in_shardings=(params_sh, batch_sh),
                out_shardings=(None, cache_sh),
            ).lower(params_spec, batch_spec)
        else:
            step = make_serve_step(cfg, ctx)
            cache_spec = SH.cache_specs(cfg, shape)
            cache_sh = SH.cache_shardings(cache_spec, cfg, ctx)
            lowered = jax.jit(
                step, in_shardings=(params_sh, cache_sh, batch_sh),
                out_shardings=(None, cache_sh),
            ).lower(params_spec, cache_spec, batch_spec)
        compiled = lowered.compile()
    return compiled, ctx


def _metrics(compiled):
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    coll_bytes = sum(v for k, v in coll.items() if k != "counts")
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll_bytes,
        "collectives": coll,
    }


def counting_pass(cfg, shape, mesh, multi_pod: bool, opt: bool = False):
    """Exact per-device totals via the P=1 / P=2 unrolled fit."""
    period = cfg.pattern_period
    rem = cfg.num_remainder_layers
    p_true = cfg.num_full_periods
    # P=2 vs P=4: GSPMD occasionally makes different global choices for a
    # 1-layer program, which made a (1,2) fit non-monotone; (2,4) is
    # stable, and per-period deltas are clamped at >= 0
    pa, pb = (2, 4) if p_true >= 2 else (1, 2)
    m = []
    for p in (pa, pb):
        c = dataclasses.replace(cfg, num_layers=p * period + rem,
                                unroll_for_costing=True)
        compiled, _ = _compile(c, shape, mesh, multi_pod, opt)
        m.append(_metrics(compiled))
    out = {}
    for key in ("flops", "bytes", "collective_bytes"):
        per_period = max((m[1][key] - m[0][key]) / (pb - pa), 0.0)
        out[key] = max(m[0][key] + (p_true - pa) * per_period, m[0][key])
        out[key + "_per_period"] = per_period
    out["collectives_p1"] = m[0]["collectives"]
    return out


def lower_and_compile(arch: str, shape_name: str, multi_pod: bool,
                      include_hlo: bool = False, counting: bool = True,
                      opt: bool = False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size

    t0 = time.time()
    compiled, ctx = _compile(cfg, shape, mesh, multi_pod, opt)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    raw = _metrics(compiled)
    corrected = counting_pass(cfg, shape, mesh, multi_pod, opt) if counting \
        else dict(raw)

    mflops = model_flops(cfg, shape, shape.kind)
    roof = roofline_terms(
        flops_per_device=corrected["flops"],
        bytes_per_device=corrected["bytes"],
        collective_bytes_per_device=corrected["collective_bytes"],
        chips=chips,
        model_flops=mflops,
    )

    def g(attr):
        return getattr(mem, attr, 0) or 0

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "tp_mode": ctx.tp,
        "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_size_bytes": g("argument_size_in_bytes"),
            "output_size_bytes": g("output_size_in_bytes"),
            "temp_size_bytes": g("temp_size_in_bytes"),
            "alias_size_bytes": g("alias_size_in_bytes"),
            "peak_bytes_per_device": (
                g("argument_size_in_bytes") + g("temp_size_in_bytes")
                + g("output_size_in_bytes") - g("alias_size_in_bytes")),
        },
        "cost_analysis_raw": {k: raw[k] for k in
                              ("flops", "bytes", "collective_bytes")},
        "cost_analysis_corrected": {
            k: corrected[k] for k in corrected if not k.startswith("coll")},
        "collectives_raw": raw["collectives"],
        "roofline": roof.to_dict(),
    }
    if include_hlo:
        result["hlo"] = compiled.as_text()
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-counting", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="beyond-paper optimized sharding (§Perf)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    jobs = []
    if args.all:
        for arch in list_archs():
            cfg = get_config(arch)
            for shape in applicable_shapes(cfg):
                for mp in (False, True):
                    jobs.append((arch, shape.name, mp))
    else:
        jobs.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape_name, mp in jobs:
        tag = f"{arch}_{shape_name}_{'2x16x16' if mp else '16x16'}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {tag}", flush=True)
            continue
        try:
            res = lower_and_compile(arch, shape_name, mp,
                                    counting=not args.no_counting,
                                    opt=args.opt)
            with open(path, "w") as f:
                json.dump(res, f, indent=2)
            r = res["roofline"]
            print(f"[ok]  {tag}: compile={res['compile_s']}s "
                  f"bottleneck={r['bottleneck']} "
                  f"compute={r['compute_s']:.2e}s "
                  f"memory={r['memory_s']:.2e}s "
                  f"collective={r['collective_s']:.2e}s "
                  f"flops_ratio={r['flops_ratio']:.2f} "
                  f"peak_mem={res['memory_analysis']['peak_bytes_per_device']/2**30:.2f}GiB",
                  flush=True)
        except Exception as e:
            failures += 1
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run failures")
    print("dry-run complete")


if __name__ == "__main__":
    main()
