"""Assigned input shapes (brief):

  train_4k       seq_len=  4,096  global_batch=256   training
  prefill_32k    seq_len= 32,768  global_batch= 32   inference prefill
  decode_32k     seq_len= 32,768  global_batch=128   one token, full cache
  long_500k      seq_len=524,288  global_batch=  1   long-context decode

long_500k eligibility: sub-quadratic / bounded-cache archs only
(DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

CACHE_PAD = 512   # decode caches get seq_len + CACHE_PAD slots (divisible
                  # by every mesh batch/seq axis product we use)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def long_context_capable(cfg: ModelConfig) -> bool:
    """True if the arch has a sub-quadratic / bounded-KV path for 500k
    decode: any recurrent kind, or a local:global attention mix."""
    from repro.configs.base import LOCAL_ATTN, RECURRENT, RWKV
    kinds = set(cfg.block_pattern)
    return bool(kinds & {RECURRENT, RWKV, LOCAL_ATTN})


def applicable_shapes(cfg: ModelConfig):
    for name, shape in SHAPES.items():
        if name == "long_500k" and not long_context_capable(cfg):
            continue
        yield shape
