"""Generic training driver: single-model LM training on any assigned
architecture (reduced or full), optionally under a mesh, with FedCGD
silo-weighted federated steps.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
      --steps 100 --batch 8 --seq 128

Full configs only make sense with real hardware; the CPU container uses
--reduced (the same code path the dry-run AOT-compiles at scale).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import synthetic_token_dataset
from repro.fl.distributed import make_train_step
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    ds = synthetic_token_dataset(cfg.vocab_size, args.seq + 1,
                                 num_classes=8, num_per_class=64)
    params = T.init(jax.random.key(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    step = jax.jit(make_train_step(cfg, None, eta=args.lr))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.steps):
        take = rng.integers(0, len(ds.inputs), size=args.batch)
        toks = jnp.asarray(ds.inputs[take])
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if cfg.family == "audio":
            batch["frame_embeddings"] = jax.random.normal(
                jax.random.key(i), (args.batch, args.seq, cfg.encoder_dim))
            batch.pop("tokens")
        if cfg.family == "vlm":
            batch["encoder_embeddings"] = jnp.zeros(
                (args.batch, cfg.num_encoder_tokens, cfg.encoder_dim))
        params, metrics = step(params, batch)
        if i % args.log_every == 0:
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    print(f"done: final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
