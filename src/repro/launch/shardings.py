"""Parameter / input / cache sharding rules for the production mesh.

Two regimes per architecture (DESIGN.md §3):

* **Megatron TP + sequence-parallel residual** when attention heads and
  d_ff divide the 16-way model axis (gemma3, moonshot, qwen3-moe,
  musicgen, llama-vision): heads/d_ff/experts/vocab column-parallel,
  residual stream sequence-sharded between layers.

* **FSDP + context parallelism** otherwise (qwen2 28H, yi 56H, arctic
  56H, recurrentgemma 10H, rwkv6 40H): parameters stored sharded over
  (data x model) and gathered per scan step; compute is token-parallel
  with queries sequence-sharded and (small, GQA) KV gathered.

MoE expert tables are always expert-sharded over 'model' + FSDP over
'data' (matching the shard_map in moe.py).  Decode KV caches shard
kv-heads over 'model' when divisible, else cache length; batch-1 decode
shards cache length over the idle batch axes too.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.shapes import CACHE_PAD, InputShape
from repro.models import transformer as T
from repro.sharding import ShardingCtx


def tp_capable(cfg: ModelConfig, model_axis_size: int = 16) -> bool:
    if cfg.num_heads and cfg.num_heads % model_axis_size != 0:
        return False
    if cfg.d_ff and cfg.d_ff % model_axis_size != 0:
        return False
    return True


def make_ctx(cfg: ModelConfig, mesh: Mesh, shape: Optional[InputShape] = None,
             opt: bool = False) -> ShardingCtx:
    multi_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    m = mesh.shape["model"]
    tp = tp_capable(cfg, m)
    # --opt: context-parallel attention — attention weights FSDP'd over
    # data, queries sequence-sharded, GQA KV gathered; kills the S<->head
    # "involuntary full rematerialization" reshards.  Measured per shape
    # (EXPERIMENTS.md §Perf): 1.87x on train, 1.08x on prefill, but a
    # REGRESSION on decode (single-token steps re-gather FSDP weights),
    # so the policy is per-job-kind.
    hybrid = opt and (shape is None or shape.kind in ("train", "prefill"))
    seq_axes = []
    if shape is not None and shape.kind in ("decode", "prefill"):
        # prefill also OUTPUTS a cache of seq_len — shard it the same way
        if shape.global_batch == 1:
            seq_axes += ["pod", "data"] if multi_pod else ["data"]
        if not (tp and cfg.num_kv_heads % m == 0):
            seq_axes.append("model")
    return ShardingCtx(mesh=mesh, batch_axes=batch_axes, model_axis="model",
                       fsdp_axes=("data",), seq_axes=tuple(seq_axes), tp=tp,
                       hybrid=hybrid)


# ---------------------------------------------------------------------------
# parameter shardings


def _dims_divisible(shape, axes_size, dim):
    return shape[dim] % axes_size == 0


def _param_rule(path: str, shape, cfg: ModelConfig, ctx: ShardingCtx):
    """PartitionSpec entries for a (possibly period-stacked) param leaf."""
    mesh = ctx.mesh
    m = ctx.model_axis
    msize = mesh.shape[m]
    dm = ("data", m)
    dmsize = mesh.shape["data"] * msize
    tp = ctx.tp
    name = path.split("/")[-1]

    stacked = path.startswith("blocks/")
    dims = list(shape[1:]) if stacked else list(shape)
    spec = [None] * len(dims)

    def fsdp_largest():
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            if dims[i] >= 1024 and dims[i] % dmsize == 0:
                spec[i] = dm
                return
        for i in order:
            if dims[i] >= 1024 and dims[i] % mesh.shape["data"] == 0:
                spec[i] = "data"
                return

    if name in ("embed",):
        # vocab over model AND d over data: the embedding GRADIENT (f32,
        # several live copies around the tied-head reshard) dominated
        # gemma3 train temps at 5.6 GiB per unsharded copy (§Perf pair 2)
        spec[0] = m if dims[0] % msize == 0 else None
        if dims[1] % mesh.shape["data"] == 0:
            spec[1] = "data"
    elif name in ("lm_head",):
        spec[1] = m if dims[1] % msize == 0 else None
    elif name in ("wq", "wk", "wv", "wo") and len(dims) == 3:
        if ctx.hybrid:
            # context-parallel attention: weights only storage-sharded
            if dims[0] % mesh.shape["data"] == 0:
                spec[0] = "data"
            elif dims[2] % mesh.shape["data"] == 0:
                spec[2] = "data"
        elif tp and name != "wo" and dims[1] % msize == 0:
            spec[1] = m
        elif tp and name == "wo" and dims[0] % msize == 0:
            spec[0] = m
        else:
            fsdp_largest()
    elif name in ("w_gate", "w_up", "w_down") and len(dims) == 3 \
            and cfg.num_experts and dims[0] == cfg.num_experts:
        # MoE expert tables: expert-sharded + FSDP (matches moe.shard_map)
        spec[0] = m
        fd = 1 if name in ("w_gate", "w_up") else 2
        if dims[fd] % mesh.shape["data"] == 0:
            spec[fd] = "data"
    elif name in ("w_gate", "w_up", "w_in", "W_k") and len(dims) == 2:
        if tp and not ctx.hybrid and dims[1] % msize == 0:
            spec[1] = m   # column parallel
        else:
            fsdp_largest()
    elif name in ("w_down", "w_out", "w_o", "W_v", "W_o") and len(dims) == 2:
        if tp and not ctx.hybrid and dims[0] % msize == 0:
            spec[0] = m   # row parallel
        else:
            fsdp_largest()
    elif name in ("W_r", "W_g", "w_x", "W_i") and len(dims) == 2:
        fsdp_largest()
    elif len(dims) >= 2 and max(dims) * min(dims) >= (1 << 22):
        fsdp_largest()

    if stacked:
        spec = [None] + spec
    return P(*spec)


def param_shardings(params_spec, cfg: ModelConfig, ctx: ShardingCtx):
    """Pytree of NamedSharding matching jax.eval_shape(init) output."""
    def one(pathspec, leaf):
        path = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                        for p in pathspec)
        return NamedSharding(ctx.mesh, _param_rule(path, leaf.shape, cfg, ctx))

    return jax.tree_util.tree_map_with_path(one, params_spec)


# ---------------------------------------------------------------------------
# activations / batch / cache


def _shardable(dim, mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0 and n > 1


def batch_shardings(batch_spec, ctx: ShardingCtx):
    mesh = ctx.mesh
    ba = ctx.batch_axes

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = [None] * leaf.ndim
        if _shardable(leaf.shape[0], mesh, ba):
            spec[0] = ba if len(ba) > 1 else ba[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_spec)


def cache_shardings(cache_spec, cfg: ModelConfig, ctx: ShardingCtx):
    mesh = ctx.mesh
    ba = ctx.batch_axes
    m = ctx.model_axis
    msize = mesh.shape[m]
    seq = ctx.seq_axes
    kv_heads_sharded = ctx.tp and cfg.num_kv_heads % msize == 0

    def one(pathspec, leaf):
        path = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                        for p in pathspec)
        stacked = path.startswith("blocks/")
        shape = leaf.shape[1:] if stacked else leaf.shape
        name = path.split("/")[-1]
        spec = [None] * len(shape)
        if name in ("k", "v") and len(shape) == 4:
            B, n, KV, hd = shape
            if _shardable(B, mesh, ba):
                spec[0] = ba if len(ba) > 1 else ba[0]
            if seq and _shardable(n, mesh, seq):
                spec[1] = tuple(seq) if len(seq) > 1 else seq[0]
            if kv_heads_sharded:
                spec[2] = m
        elif name in ("wkv", "shift1", "shift2", "h", "conv") and shape:
            if _shardable(shape[0], mesh, ba):
                spec[0] = ba if len(ba) > 1 else ba[0]
        if stacked:
            spec = [None] + spec
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_spec)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins (no allocation — the dry-run contract)


def input_specs(cfg: ModelConfig, shape: InputShape, federated: bool = False):
    """ShapeDtypeStructs for every model input of this (arch, shape)."""
    B = shape.global_batch
    S = shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32

    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "audio":
            batch.pop("tokens")
            batch["frame_embeddings"] = jax.ShapeDtypeStruct(
                (B, S, cfg.encoder_dim), dt)
        if cfg.family == "vlm":
            batch["encoder_embeddings"] = jax.ShapeDtypeStruct(
                (B, cfg.num_encoder_tokens, cfg.encoder_dim), dt)
        if shape.kind == "train":
            batch["targets"] = jax.ShapeDtypeStruct((B, S), i32)
            if federated:
                batch["schedule_weights"] = jax.ShapeDtypeStruct(
                    (B,), jnp.float32)
        return batch

    # decode: one token + cache of seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.family == "audio":
        batch.pop("tokens")
        batch["frame_embeddings"] = jax.ShapeDtypeStruct(
            (B, 1, cfg.encoder_dim), dt)
    return batch


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: T.init(jax.random.key(0), cfg))


def cache_specs(cfg: ModelConfig, shape: InputShape):
    cache_len = shape.seq_len + CACHE_PAD
    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, cache_len))
