"""Minimal functional optimizers (optax-style, no external deps).

FedAvg's local update (paper Eq. 1) is plain SGD; momentum / AdamW are
provided for the non-federated training drivers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]   # (grads, state, params) -> (updates, state)


def sgd(learning_rate: float, momentum: float = 0.0,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params):
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p,
                                 grads, params)
        if momentum == 0.0:
            return jax.tree.map(lambda g: -learning_rate * g, grads), state
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
        return jax.tree.map(lambda m: -learning_rate * m, new_m), new_m

    return Optimizer(init, update)


def adamw(learning_rate: float, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
        return {"m": z, "v": jax.tree.map(jnp.copy, z),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], g32)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         state["v"], g32)
        mh = jax.tree.map(lambda m: m / (1 - b1 ** t.astype(jnp.float32)), m)
        vh = jax.tree.map(lambda v: v / (1 - b2 ** t.astype(jnp.float32)), v)
        upd = jax.tree.map(
            lambda mh, vh, p: (-learning_rate
                               * (mh / (jnp.sqrt(vh) + eps)
                                  + weight_decay * p.astype(jnp.float32))
                               ).astype(p.dtype),
            mh, vh, params)
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def make_optimizer(name: str, learning_rate: float, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(learning_rate, **kw)
    if name == "adamw":
        return adamw(learning_rate, **kw)
    raise ValueError(name)
