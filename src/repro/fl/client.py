"""Client-side local update (paper Eq. 1): tau mini-batch SGD steps.

``make_local_update`` returns a jitted function that runs every available
device's local update *in one XLA program* via vmap over the device axis —
the single-host simulation analogue of devices computing in parallel.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


def make_local_update(loss_fn: Callable, eta: float, tau: int):
    """loss_fn(params, batch, rng) -> (loss, metrics).

    Returns update(params, batches, rng) where ``batches`` is a pytree
    whose leaves have leading dims [num_devices, tau, batch, ...]; the
    same initial params are used by every device (edge model broadcast).
    Output params have a leading [num_devices] dim; also returns the mean
    loss per device [num_devices]."""

    def one_device(params, dev_batches, rng):
        def step(carry, xs):
            p, r = carry
            batch, = xs
            r, sub = jax.random.split(r)
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(p, batch, sub)
            p = jax.tree.map(lambda a, g: a - eta * g.astype(a.dtype),
                             p, grads)
            return (p, r), loss

        (params, _), losses = jax.lax.scan(
            step, (params, rng), (dev_batches,))
        return params, losses.mean()

    @jax.jit
    def update(params, batches, rng):
        num_dev = jax.tree.leaves(batches)[0].shape[0]
        rngs = jax.random.split(rng, num_dev)
        return jax.vmap(one_device, in_axes=(None, 0, 0))(
            params, batches, rngs)

    return update


def set_device(stacked, v: int, tree):
    """Write one device's pytree into the stacked [V, ...] upload buffer
    (inverse of ``server.select_device``) — used by the fault layer to
    substitute corrupted or clipped uploads."""
    return jax.tree.map(lambda s, x: s.at[v].set(x), stacked, tree)


def model_delta(new_params, old_params):
    """g_v = w_v^{(j+1)} - w_v^{(j)} (uploaded payload)."""
    return jax.tree.map(lambda a, b: a - b, new_params, old_params)


def payload_bits(params, bits_per_param: int = 32) -> float:
    """D_w: uplink payload size of one model update."""
    return sum(x.size for x in jax.tree.leaves(params)) * bits_per_param
