"""Client-side local update (paper Eq. 1): tau mini-batch SGD steps.

``make_local_update`` returns a jitted function that runs every available
device's local update *in one XLA program* via vmap over the device axis —
the single-host simulation analogue of devices computing in parallel.

``make_round_core`` fuses the whole client half of a round — local update
(Eq. 1), per-device sigma estimation (Eq. 10), model deltas and their L2
norms — into one XLA program batched over a leading *cell* axis, so the
host pulls everything scheduling needs in a single device->host sync.
``FederatedTrainer`` calls it with one cell; ``MultiCellTrainer`` drives C
cells per aggregation step through the same program.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.estimation import tree_norm


def make_local_update(loss_fn: Callable, eta: float, tau: int):
    """loss_fn(params, batch, rng) -> (loss, metrics).

    Returns update(params, batches, rng) where ``batches`` is a pytree
    whose leaves have leading dims [num_devices, tau, batch, ...]; the
    same initial params are used by every device (edge model broadcast).
    Output params have a leading [num_devices] dim; also returns the mean
    loss per device [num_devices]."""

    def one_device(params, dev_batches, rng):
        def step(carry, xs):
            p, r = carry
            batch, = xs
            r, sub = jax.random.split(r)
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(p, batch, sub)
            p = jax.tree.map(lambda a, g: a - eta * g.astype(a.dtype),
                             p, grads)
            return (p, r), loss

        (params, _), losses = jax.lax.scan(
            step, (params, rng), (dev_batches,))
        return params, losses.mean()

    @jax.jit
    def update(params, batches, rng):
        num_dev = jax.tree.leaves(batches)[0].shape[0]
        rngs = jax.random.split(rng, num_dev)
        return jax.vmap(one_device, in_axes=(None, 0, 0))(
            params, batches, rngs)

    return update


def make_round_core(loss_fn: Callable, sigma_fn: Callable, eta: float,
                    tau: int, cell_axis: str = "auto"):
    """Fused device-resident round core, batched over cells.

    loss_fn(params, batch, rng) -> (loss, metrics);
    sigma_fn(params, batch) -> scalar sigma_v (Eq. 10).

    Returns core(params, batches, rngs) where ``params`` is a pytree with
    a leading [C] cell axis (each cell's broadcast model), ``batches`` a
    pytree with leading dims [C, V, tau, batch, ...] and ``rngs`` a [C]
    key array.  One XLA program computes, per cell:

      dev_params  [C, V, ...]  post-local-update device models
      losses      [C, V]       mean local loss per device
      sigma_v     [C, V]       Eq. 10 on each device's first batch
      deltas      [C, V, ...]  dev_params - params (the upload payload)
      delta_norms [C, V]       per-device L2 norms of the deltas
      finite      [C, V] bool  every delta leaf is finite (the server's
                               NaN/Inf guard, computed in-graph so the
                               sanitizer needs no extra device round-trip)

    so the trainer makes exactly one host sync between local update and
    scheduling (down from O(V) per-device pulls).

    ``cell_axis`` picks how the cell axis is executed inside the one
    program: ``"vmap"`` batches it (cells run lockstep in parallel —
    right for accelerators), ``"scan"`` rolls it with ``jax.lax.map``
    (the compiled body is the single-cell program, so C cells compile
    once and each cell's numerics are *identical* to a standalone
    trainer's — right for CPU, where vmapping per-device conv weights
    lowers to C*V-group grouped convolutions that are expensive to
    compile and execute).  ``"auto"`` scans on CPU, vmaps elsewhere."""

    def one_device(params, dev_batches, rng):
        def step(carry, xs):
            p, r = carry
            batch, = xs
            r, sub = jax.random.split(r)
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(p, batch, sub)
            p = jax.tree.map(lambda a, g: a - eta * g.astype(a.dtype),
                             p, grads)
            return (p, r), loss

        (params, _), losses = jax.lax.scan(
            step, (params, rng), (dev_batches,))
        return params, losses.mean()

    def one_cell(params, batches, rng):
        num_dev = jax.tree.leaves(batches)[0].shape[0]
        rngs = jax.random.split(rng, num_dev)
        dev_params, losses = jax.vmap(one_device, in_axes=(None, 0, 0))(
            params, batches, rngs)
        first = jax.tree.map(lambda x: x[:, 0], batches)
        sigma_v = jax.vmap(sigma_fn, in_axes=(None, 0))(params, first)
        deltas = jax.tree.map(lambda new, old: new - old[None],
                              dev_params, params)
        delta_norms = jax.vmap(tree_norm)(deltas)
        finite = None
        for x in jax.tree.leaves(deltas):
            f = jnp.isfinite(x).reshape(x.shape[0], -1).all(axis=1)
            finite = f if finite is None else finite & f
        return dev_params, losses, sigma_v, deltas, delta_norms, finite

    if cell_axis == "auto":
        cell_axis = "scan" if jax.default_backend() == "cpu" else "vmap"
    if cell_axis == "vmap":
        return jax.jit(jax.vmap(one_cell))
    if cell_axis != "scan":
        raise ValueError(f"cell_axis must be auto|vmap|scan, "
                         f"got {cell_axis!r}")

    @jax.jit
    def core(params_c, batches_c, rngs_c):
        return jax.lax.map(lambda a: one_cell(*a),
                           (params_c, batches_c, rngs_c))

    return core


def set_device(stacked, v: int, tree):
    """Write one device's pytree into the stacked [V, ...] upload buffer
    (inverse of ``server.select_device``) — used by the fault layer to
    substitute corrupted or clipped uploads."""
    return jax.tree.map(lambda s, x: s.at[v].set(x), stacked, tree)


def set_devices(stacked, idx, trees_stacked):
    """Write many devices' pytrees into the stacked [V, ...] buffer in a
    single scatter per leaf (batched ``set_device``): ``idx`` [K] device
    indices, ``trees_stacked`` a pytree with leading [K] axis."""
    idx = jnp.asarray(idx)
    return jax.tree.map(lambda s, x: s.at[idx].set(x.astype(s.dtype)),
                        stacked, trees_stacked)


def model_delta(new_params, old_params):
    """g_v = w_v^{(j+1)} - w_v^{(j)} (uploaded payload)."""
    return jax.tree.map(lambda a, b: a - b, new_params, old_params)


def payload_bits(params, bits_per_param: int = 32) -> float:
    """D_w: uplink payload size of one model update."""
    return sum(x.size for x in jax.tree.leaves(params)) * bits_per_param
