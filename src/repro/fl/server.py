"""Edge-server aggregation (paper Eq. 2) + the fused finalize core.

``aggregate`` is the reference eager implementation; the trainers'
round hot path goes through ``make_finalize_core``, which fuses Eq. 2
and the Eq. 12 centered-gradient norms into ONE jitted dispatch batched
over a leading cell axis (same op order as ``aggregate``, so the two
agree bitwise for a single cell)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimation import tree_norm


def aggregate(device_params, mask: np.ndarray, weights: np.ndarray = None):
    """Weighted FedAvg over scheduled devices.

    device_params: pytree with leading [V] device dim (vmapped local
    update output); mask [V] bool; weights default alpha_v = 1/|Pi|
    (equal dataset sizes, paper Sec. V-A)."""
    mask = np.asarray(mask, dtype=np.float64)
    s = mask.sum()
    if s == 0:
        raise ValueError(
            "aggregate() called with an all-False schedule mask — "
            "averaging zero uploads would silently zero the model; the "
            "caller must keep the previous round's params instead "
            "(see FederatedTrainer.run_round's zero-upload path)")
    if weights is None:
        weights = mask / max(s, 1.0)
    else:
        weights = np.asarray(weights) * mask
        weights = weights / max(weights.sum(), 1e-12)
    w = jnp.asarray(weights, dtype=jnp.float32)

    def agg(leaf):
        wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)
        return (leaf.astype(jnp.float32) * wb).sum(0).astype(leaf.dtype)

    return jax.tree.map(agg, device_params)


def select_device(device_params, v: int):
    return jax.tree.map(lambda x: x[v], device_params)


def make_finalize_core(tau: int, eta: float, cell_axis: str = "auto",
                       donate: str = "auto"):
    """Fused server-side finalize, batched over cells.

    Returns ``core(params, dev_params, deltas, w, active)`` where every
    argument carries a leading [C] cell axis: ``params`` [C, ...] the
    pre-round models, ``dev_params`` / ``deltas`` [C, V, ...] the round
    core's outputs (with any sanitizer replacements already scattered
    in), ``w`` [C, V] f32 the Eq. 2 upload weights (upload_v / |uploads|,
    all-zero rows for zero-upload cells and padded device rows) and
    ``active`` [C] bool (True = this cell aggregates).  One XLA program
    computes, per cell:

      new_params [C, ...]  Eq. 2 weighted sum where the cell had uploads,
                           else the previous params (an in-graph select,
                           so zero-upload cells cost no extra dispatch)
      norms      [C, V]    || grad_v - sum_u w_u grad_u || with
                           grad_v = -delta_v / (tau * eta) — the Eq. 12
                           numerators; rows with w_v = 0 are garbage and
                           must be masked by the caller

    ``cell_axis`` follows ``make_round_core``: ``"scan"`` rolls the cell
    axis with ``lax.map`` (the compiled body is the single-cell program,
    so a C-cell finalize is bitwise-identical to C standalone ones —
    the CPU default), ``"vmap"`` batches it for accelerators.

    ``donate="auto"`` donates the dev_params/deltas buffers to the
    computation on accelerator backends (they are dead after finalize);
    CPU keeps them, where jax buffer donation is unsupported."""

    def one_cell(args):
        params, dev_params, deltas, w, active = args

        def agg_leaf(leaf):
            wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1))
            return (leaf.astype(jnp.float32) * wb).sum(0).astype(leaf.dtype)

        agg = jax.tree.map(agg_leaf, dev_params)
        new_params = jax.tree.map(lambda a, p: jnp.where(active, a, p),
                                  agg, params)

        # Eq. 12 numerators: ||grad_v - mean|| with grad = -delta/(tau*eta).
        # Centering commutes with the scale, so the deltas are centered
        # RAW and the norms divided afterwards: with exact {0, 1/|U|}
        # weights a single-upload cell's centered row is then exactly
        # zero (d_r - d_r), so its norm is exactly 0 and the host-side
        # `g > 0` refresh guard skips it.  Folding the division into the
        # graph lets XLA reassociate it through the weighted mean,
        # leaving ulp-level residue that turns the zero into ~1e-7 and
        # silently collapses g_hat.
        def center(x):
            a = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
            return x - (x * a).sum(0)[None]

        norms = jax.vmap(tree_norm)(jax.tree.map(center, deltas)) \
            / (tau * eta)
        return new_params, norms

    if cell_axis == "auto":
        cell_axis = "scan" if jax.default_backend() == "cpu" else "vmap"
    if cell_axis not in ("scan", "vmap"):
        raise ValueError(f"cell_axis must be auto|vmap|scan, "
                         f"got {cell_axis!r}")
    kw = {}
    if donate == "auto" and jax.default_backend() != "cpu":
        kw["donate_argnums"] = (1, 2)

    if cell_axis == "vmap":
        return jax.jit(jax.vmap(
            lambda p, dp, d, w, a: one_cell((p, dp, d, w, a))), **kw)

    @partial(jax.jit, **kw)
    def core(params_c, dev_params_c, deltas_c, w_c, active_c):
        return jax.lax.map(one_cell, (params_c, dev_params_c, deltas_c,
                                      w_c, active_c))

    return core
