"""Edge-server aggregation (paper Eq. 2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def aggregate(device_params, mask: np.ndarray, weights: np.ndarray = None):
    """Weighted FedAvg over scheduled devices.

    device_params: pytree with leading [V] device dim (vmapped local
    update output); mask [V] bool; weights default alpha_v = 1/|Pi|
    (equal dataset sizes, paper Sec. V-A)."""
    mask = np.asarray(mask, dtype=np.float64)
    s = mask.sum()
    if s == 0:
        raise ValueError(
            "aggregate() called with an all-False schedule mask — "
            "averaging zero uploads would silently zero the model; the "
            "caller must keep the previous round's params instead "
            "(see FederatedTrainer.run_round's zero-upload path)")
    if weights is None:
        weights = mask / max(s, 1.0)
    else:
        weights = np.asarray(weights) * mask
        weights = weights / max(weights.sum(), 1e-12)
    w = jnp.asarray(weights, dtype=jnp.float32)

    def agg(leaf):
        wb = w.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(jnp.float32)
        return (leaf.astype(jnp.float32) * wb).sum(0).astype(leaf.dtype)

    return jax.tree.map(agg, device_params)


def select_device(device_params, v: int):
    return jax.tree.map(lambda x: x[v], device_params)
