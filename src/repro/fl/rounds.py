"""FedCGD — Algorithm 3: the full federated round loop.

Per round j:
  1. device availability ~ Bernoulli(p_a); channel gains drawn from the
     TR 38.901 cell -> minimum bandwidths B_v* (Eq. 9)
  2. edge broadcasts w^{(j)}; every available device runs tau local SGD
     steps (Eq. 1) — vmapped into one XLA program
  3. devices report sigma_v (Eq. 10) and p_v over the sampled data
  4. server solves P1 (GS / FSCD / FSCD-Gc or a baseline policy)
  5. scheduled devices upload — each upload can fail (dropout, compute
     straggling, a second shadow-fading draw breaking Eq. 9) or arrive
     corrupted; the server sanitizes deltas (NaN/Inf guard + norm
     clip), backfills failed slots by re-solving P1 over the surviving
     feasible devices with the residual bandwidth, and aggregates the
     uploads that actually landed (Eq. 2, weights renormalized)
  6. server refreshes G (Eq. 12) from the landed deltas; on a
     zero-upload round it skips aggregation and decays sigma-hat /
     G-hat toward their priors instead of freezing stale estimates

The fault model lives in ``repro.faults`` and is configured through
``FLConfig.faults``; with every probability at zero (the default) the
loop reproduces the fault-free trainer bitwise.  Every round record
carries failure telemetry (``num_failed``, ``failure_causes``,
``num_backfilled``, ``num_sanitized``, ...), and the ``repro.obs``
layer (``FLConfig.obs``, off by default) adds round-phase spans, a
metrics registry and per-round record sinks on top of it.

The round hot path is *fused*: one cell-batched XLA program
(``repro.fl.client.make_round_core``) runs the local updates, the Eq. 10
sigma estimates, the deltas and their L2 norms, and the host pulls all
scheduling inputs in a single device->host sync (``last_round_host_syncs``
counts the pulls; the fault-free round makes 2, down from O(V) in the
per-device-loop implementation).  ``run_round`` is decomposed into
reusable phases (``_prepare_round`` / ``_post_core`` / ``_make_problem``
/ ``_upload_phase`` / ``_backfill_problem`` / ``_apply_backfill`` /
``_finalize_round``) so ``repro.fl.multicell.MultiCellTrainer`` can drive
C cells through the same round core with one batched ``solve_many``
scheduling dispatch per round.

The trainer is model-agnostic (CNNs for the paper's experiments; any
model-zoo architecture through the same interface).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import CNNConfig
from repro.core import scheduling as S
from repro.core import estimation as E
from repro.core.bandwidth import min_bandwidth
from repro.core.wemd import wemd_of_set
from repro.data.datasets import ArrayDataset
from repro.faults.config import FaultConfig
from repro.faults.injector import FAILURE_CAUSES, FaultInjector
from repro.faults.sanitize import sanitize_updates
from repro.fl.client import (make_local_update, make_round_core,
                             payload_bits, set_device, set_devices)
from repro.fl.server import make_finalize_core
from repro.models.registry import Model
from repro.obs import ObsConfig
from repro.obs import from_config as obs_from_config
from repro.wireless.channel import CellState, make_cell


@dataclasses.dataclass
class FLConfig:
    """One FL experiment (paper Table I + engine knobs).

    ``obs`` configures the observability layer (``repro.obs``), off by
    default — with ``ObsConfig(enabled=False)`` the trainer holds the
    shared no-op facade and a fault-free round is bitwise-identical to
    the uninstrumented trainer.  When enabled:

      * ``obs.jsonl_path`` streams one JSON record per round (failure
        telemetry + ``phases`` span breakdown + ``round_s``) to a file;
      * ``obs.ring_size`` keeps the last N records in memory
        (``trainer.obs.records()``);
      * ``obs.console`` prints a one-line digest per round;
      * span timings, host-sync counts, upload bytes, scheduler
        iterations, failure causes and XLA compile counts/seconds land
        in ``trainer.obs.metrics`` (names in ROADMAP.md Observability).
    """
    num_devices: int = 64
    available_prob: float = 0.3
    batch_size: int = 32
    tau: int = 1
    eta: float = 0.1
    deadline_s: float = 2.0
    scheduler: str = "fedcgd-fscd"
    scheduler_backend: str = "numpy"     # "numpy" | "jax" (batched engine)
    scheduler_pallas: Optional[bool] = None  # None = auto (TPU only); the
    #   jax backend then routes its f32 candidate scans through the
    #   Pallas wemd_swap / wemd_add kernels (f64 stays the CPU default)
    num_cells: int = 1                   # cells per aggregation step
    #   (used by repro.fl.multicell.MultiCellTrainer; a plain
    #   FederatedTrainer always simulates exactly one cell)
    poc_candidates: int = 16
    bits_per_param: int = 32
    payload_bits_override: float = 0.0   # 0 = derive from model size
    seed: int = 0
    sigma_init: float = 1.0
    g_init: float = 1.0
    eval_every: int = 5
    ucb_beta: float = 0.05
    faults: FaultConfig = dataclasses.field(default_factory=FaultConfig)
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)


SCHEDULERS = ("fedcgd-fscd", "fedcgd-gs", "fedcgd-fscd-gc", "fedcgd-cd",
              "bc", "bn", "poc", "fcbs", "random")


@dataclasses.dataclass
class RoundPrep:
    """Host-side round inputs (availability, channel, sampled batches)."""
    avail: np.ndarray          # [V] bool
    avail_idx: np.ndarray      # [V_av]
    gains: np.ndarray          # [V] scheduling-time channel gains
    bstar: np.ndarray          # [V] Eq. 9 minimum bandwidths
    batches: object            # pytree, leaves [V_av, tau, b, ...]
    p_sampled: np.ndarray      # [V_av, C] sampled label histograms
    subkey: object             # per-round jax PRNG key


@dataclasses.dataclass
class UploadState:
    """Mutable upload-phase outcome threaded through backfill/aggregate."""
    upload: np.ndarray         # [V_av] bool — uploads entering Eq. 2
    mod_deltas: Dict           # local idx -> replacement delta pytree
    cause_counts: Dict[str, int]
    arrived: np.ndarray        # [V_av] bool — pre-sanitize arrivals
    rf: object                 # RoundFaults
    upload_gains: np.ndarray   # [V] gains at upload time
    num_dropped_nf: int = 0
    num_clipped: int = 0
    num_bf_scheduled: int = 0
    num_backfilled: int = 0


class FederatedTrainer:
    def __init__(self, model: Model, train: ArrayDataset, test: ArrayDataset,
                 device_indices: List[np.ndarray], cfg: FLConfig,
                 cell: Optional[CellState] = None):
        self.model = model
        self.train = train
        self.test = test
        self.device_indices = device_indices
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.jkey = jax.random.key(cfg.seed)
        self.cell = cell or make_cell(cfg.num_devices, self.rng)

        C = train.num_classes
        from repro.data.partition import label_distributions
        self.p_dev = label_distributions(train.labels, device_indices, C)
        sizes = np.array([len(i) for i in device_indices], dtype=np.float64)
        self.dev_sizes = sizes
        all_idx = np.concatenate(device_indices)
        self.global_dist = np.bincount(train.labels[all_idx],
                                       minlength=C) / len(all_idx)
        self.num_classes = C

        self.params = model.init(jax.random.key(cfg.seed + 1))
        self.sigma_hat = cfg.sigma_init
        self.g_hat = cfg.g_init
        self.g_hat_c = np.full(C, cfg.g_init)
        self.payload = (cfg.payload_bits_override
                        or payload_bits(self.params, cfg.bits_per_param))
        self.plays = np.zeros(cfg.num_devices)       # Fed-CBS counters
        self.cum_loss = np.zeros(cfg.num_devices)    # POC statistics
        self.history: List[Dict] = []
        # observability facade (the shared no-op DISABLED when off)
        self.obs = obs_from_config(cfg.obs)
        self.faults = FaultInjector(cfg.faults, cfg.num_devices, cfg.seed,
                                    obs=self.obs)
        self.g_refresh_errors = 0                    # cumulative Eq. 12 skips
        self._obs_sched_iters = 0                    # last round, for obs

        self._local_update = make_local_update(self._loss, cfg.eta, cfg.tau)
        # instrument_jit is the identity when obs is disabled; enabled,
        # it counts XLA compiles + compile seconds per core
        self._round_core = self.obs.instrument_jit(
            "round_core", make_round_core(self._loss, self._sigma_one,
                                          cfg.eta, cfg.tau))
        self._sigma_all = jax.jit(jax.vmap(self._sigma_one,
                                           in_axes=(None, 0)))
        # fused finalize hot path: Eq. 2 weighted sum (the op order of
        # ``server.aggregate``) + the Eq. 12 centered-gradient norms in
        # ONE cell-batched dispatch (zero-upload cells keep their params
        # through an in-graph select)
        self._finalize_core = self.obs.instrument_jit(
            "finalize_core", make_finalize_core(cfg.tau, cfg.eta))
        self._eval_batch = self.obs.instrument_jit(
            "eval", jax.jit(self._eval_fn))
        self.last_round_host_syncs = 0       # device->host pulls between
        #   local update and aggregation (fused round contract: <= 3)

        # single-class-per-device detection (enables FSCD-Gc)
        self.device_class = self.p_dev.argmax(axis=1)
        self.single_class = bool((self.p_dev.max(axis=1) > 0.999).all())

    # ------------------------------------------------------------------
    def _loss(self, params, batch, rng=None):
        return self.model.loss_fn(params, batch, rng)

    def _eval_fn(self, params, batch):
        if isinstance(self.model.cfg, CNNConfig):
            logits = self.model.forward(params, batch)
        else:
            logits, _, _ = self.model.forward(params, batch)
            logits = logits[:, -1]
        return logits.argmax(-1)

    def make_batch(self, inputs, labels):
        if isinstance(self.model.cfg, CNNConfig):
            return {"images": jnp.asarray(inputs),
                    "labels": jnp.asarray(labels)}
        toks = jnp.asarray(inputs)
        targets = jnp.concatenate(
            [toks[..., 1:], toks[..., -1:]], axis=-1)
        mask = jnp.ones(toks.shape, jnp.float32).at[..., -1].set(0.0)
        return {"tokens": toks, "targets": targets, "loss_mask": mask}

    # ------------------------------------------------------------------
    def _device_batches(self, avail: np.ndarray):
        """Stacked batches [V_av, tau, b, ...] + per-device sampled label
        histograms (paper: p_v over the sampled data)."""
        cfg = self.cfg
        xs, ys, hists = [], [], []
        for v in np.flatnonzero(avail):
            idx = self.device_indices[v]
            take = self.rng.choice(idx, size=cfg.tau * cfg.batch_size,
                                   replace=len(idx) < cfg.tau * cfg.batch_size)
            xs.append(self.train.inputs[take])
            ys.append(self.train.labels[take])
            hists.append(np.bincount(self.train.labels[take],
                                     minlength=self.num_classes)
                         / len(take))
        x = np.stack(xs).reshape((len(xs), cfg.tau, cfg.batch_size)
                                 + xs[0].shape[1:])
        y = np.stack(ys).reshape(len(ys), cfg.tau, cfg.batch_size)
        # one make_batch over the stacked [V_av, tau, b, ...] arrays: a
        # single host->device transfer per leaf instead of O(V) eager
        # per-device conversions + stacks (make_batch is leading-dim
        # agnostic, so the values are unchanged)
        return self.make_batch(x, y), np.stack(hists)

    def _estimate_sigmas(self, avail_idx, batches):
        """Eq. 10 via the last-layer decomposition on the first batch —
        all V devices in one vmapped jit call + one host pull (the fused
        round core computes the same quantity inline)."""
        first = jax.tree.map(lambda x: x[:, 0], batches)
        return np.asarray(self._sigma_all(self.params, first),
                          dtype=np.float64)

    def _sigma_one(self, params, batch):
        if isinstance(self.model.cfg, CNNConfig):
            from repro.models import cnn as C
            feats, logits = _cnn_features_logits(params, self.model.cfg,
                                                 batch["images"])
            return E.sigma_hat_lastlayer(feats, logits, batch["labels"])
        logits, _, _ = self.model.forward(params, batch)
        # per-sequence CE-grad proxy at the final position
        return E.sigma_hat_lastlayer(
            jnp.ones((logits.shape[0], 1)), logits[:, -1],
            batch["targets"][:, -1])

    # ------------------------------------------------------------------
    def _schedule(self, prob: S.Problem, avail_idx, gains, delta_norms,
                  round_idx) -> S.Schedule:
        cfg = self.cfg
        name = cfg.scheduler
        backend = cfg.scheduler_backend
        if backend not in ("numpy", "jax"):
            raise ValueError(f"unknown scheduler_backend: {backend!r}")
        if name == "fedcgd-gs":
            if backend == "jax":
                return S.solve_many([prob], "gs", backend="jax",
                                    pallas=cfg.scheduler_pallas,
                                    obs=self.obs)[0]
            return S.greedy_scheduling(prob)
        if name in ("fedcgd-fscd", "fedcgd-fscd-gc"):
            if backend == "jax":
                return S.solve_many([prob], "fscd", backend="jax",
                                    pallas=cfg.scheduler_pallas,
                                    obs=self.obs)[0]
            return S.fscd(prob)
        if name == "fedcgd-cd":
            return S.coordinate_descent(prob, self.rng)
        if name == "bc":
            return S.best_channel(prob, gains[avail_idx])
        if name == "bn":
            return S.best_norm(prob, delta_norms)
        if name == "poc":
            return S.power_of_choice(prob, self.cum_loss[avail_idx],
                                     cfg.poc_candidates, self.rng)
        if name == "fcbs":
            return S.fed_cbs(prob, self.plays[avail_idx], round_idx,
                             cfg.ucb_beta, self.rng)
        if name == "random":
            return S.random_schedule(prob, self.rng)
        raise ValueError(name)

    # ------------------------------------------------------------------
    def _corrupt_overrides(self, rf, arrived, avail_idx, deltas) -> Dict:
        """Replacement deltas for uploads damaged in transit."""
        out = {}
        if not (self.faults.enabled and self.cfg.faults.corrupt_prob > 0):
            return out
        for i in np.flatnonzero(arrived):
            v = avail_idx[i]
            if rf.corrupt[v]:
                out[int(i)] = self.faults.corrupt_delta(
                    jax.tree.map(lambda x, i=i: x[i], deltas),
                    self.faults.corrupt_mode_of(rf, v))
        return out

    def _backfill_problem(self, prob, sched, st: UploadState,
                          prep: RoundPrep) -> Optional[S.Problem]:
        """One-shot reschedule after upload failures: the P1 instance
        over the surviving feasible devices (available, unscheduled, not
        dropped out) under the residual bandwidth, at upload-time gains.
        Returns None when no residual bandwidth / no feasible device."""
        cfg = self.cfg
        avail_idx = prep.avail_idx
        residual = self.cell.params.total_bandwidth_hz \
            - float(prep.bstar[avail_idx[st.arrived]].sum())
        if residual <= 0:
            return None
        bf_bw = min_bandwidth(
            self.payload, cfg.deadline_s,
            self.cell.received_power(st.upload_gains),
            self.cell.params.noise_psd_w)[avail_idx]
        blocked = sched.mask | st.rf.dropout[avail_idx]
        bf_bw = np.where(blocked, -1.0, bf_bw)
        if not ((bf_bw > 0) & (bf_bw <= residual)).any():
            return None
        return dataclasses.replace(prob, min_bw=bf_bw, total_bw=residual)

    def _apply_backfill(self, bf: S.Schedule, st: UploadState,
                        prep: RoundPrep, deltas, delta_norms,
                        finite=None) -> None:
        """Fold a solved backfill schedule into the upload state.

        Backfilled uploads are treated as freshly channel-measured (no
        second outage draw) but still face corruption + sanitization."""
        if not bf.mask.any():
            return
        avail_idx = prep.avail_idx
        self.plays[avail_idx[bf.mask]] += 1
        overrides = self._corrupt_overrides(st.rf, bf.mask, avail_idx,
                                            deltas)
        san = sanitize_updates(deltas, np.flatnonzero(bf.mask), overrides,
                               self.cfg.faults.clip_delta_norm,
                               norms=delta_norms, finite=finite)
        if finite is None or overrides:
            self.last_round_host_syncs += 1
        st.cause_counts["corrupt"] += len(san.dropped_nonfinite)
        st.num_bf_scheduled += int(bf.num_scheduled)
        st.num_dropped_nf += len(san.dropped_nonfinite)
        st.num_clipped += len(san.clipped)
        st.num_backfilled += len(san.kept)
        st.mod_deltas.update(san.deltas)
        st.upload[san.kept] = True

    # ------------------------------------------------------------------
    # round phases (shared with repro.fl.multicell.MultiCellTrainer)

    def _draw_avail(self):
        """Device availability ~ Bernoulli(p_a), forced non-empty."""
        cfg = self.cfg
        avail = self.rng.random(cfg.num_devices) < cfg.available_prob
        if not avail.any():
            avail[self.rng.integers(cfg.num_devices)] = True
        return avail, np.flatnonzero(avail)

    def _prep_from_channel(self, j: int, avail: np.ndarray,
                           avail_idx: np.ndarray, gains: np.ndarray,
                           bstar: np.ndarray) -> RoundPrep:
        """Sampled batches + per-round PRNG key for a given availability
        and channel realisation (the RNG tail of ``_prepare_round``;
        split out so the multi-cell driver can batch the channel math
        across cells between the two RNG passes)."""
        batches, p_sampled = self._device_batches(avail)
        self.jkey, sub = jax.random.split(self.jkey)
        return RoundPrep(avail=avail, avail_idx=avail_idx, gains=gains,
                         bstar=bstar, batches=batches,
                         p_sampled=p_sampled, subkey=sub)

    def _prepare_round(self, j: int) -> RoundPrep:
        """Host-side round inputs: availability, channel, Eq. 9
        bandwidths, sampled batches, per-round PRNG key."""
        cfg = self.cfg
        avail, avail_idx = self._draw_avail()
        gains = self.cell.draw_gains(self.rng)
        rx_power = self.cell.received_power(gains)
        bstar = min_bandwidth(self.payload, cfg.deadline_s, rx_power,
                              self.cell.params.noise_psd_w)
        return self._prep_from_channel(j, avail, avail_idx, gains, bstar)

    def _post_core(self, prep: RoundPrep, dev_losses: np.ndarray,
                   sigma_v: np.ndarray) -> None:
        """Fold the round core's host pulls into the running estimates
        (POC loss statistics, Eq. 11 global sigma)."""
        self.cum_loss[prep.avail_idx] = (0.9 * self.cum_loss[prep.avail_idx]
                                         + dev_losses)
        alpha_av = np.ones(len(prep.avail_idx)) / len(prep.avail_idx)
        self.sigma_hat = E.sigma_hat_global(sigma_v, alpha_av)

    def _make_problem(self, prep: RoundPrep) -> S.Problem:
        cfg = self.cfg
        cw = (self.g_hat_c if cfg.scheduler == "fedcgd-fscd-gc"
              else np.full(self.num_classes, self.g_hat))
        return S.Problem(
            p_dev=prep.p_sampled, global_dist=self.global_dist,
            class_weights=cw, sigma=self.sigma_hat,
            batch_size=cfg.batch_size, min_bw=prep.bstar[prep.avail_idx],
            total_bw=self.cell.params.total_bandwidth_hz)

    def _upload_phase(self, j: int, prep: RoundPrep, sched: S.Schedule,
                      deltas, delta_norms, finite=None,
                      rf=None) -> UploadState:
        """Fault injection + server-side sanitization for one round's
        scheduled uploads (backfill is the caller's second pass).

        ``finite`` carries the round core's per-device NaN/Inf-guard
        flags (no sanitizer device round-trip when provided); ``rf``
        a pre-drawn fault realisation (the multi-cell driver draws all
        cells in one batched pass)."""
        cfg = self.cfg
        avail_idx = prep.avail_idx
        mask_global = np.zeros(cfg.num_devices, bool)
        mask_global[avail_idx[sched.mask]] = True
        self.plays[mask_global] += 1

        inj = self.faults
        if rf is None:
            rf = inj.draw(j)
        upload_gains = inj.upload_gains(prep.gains, rf)
        cause = inj.arrival_failures(
            rf, mask_global, prep.bstar, self.payload, cfg.deadline_s,
            self.cell.received_power(upload_gains),
            self.cell.params.noise_psd_w)
        cause_counts = {c: 0 for c in FAILURE_CAUSES}
        arrived = sched.mask.copy()             # local (avail) index space
        for i in np.flatnonzero(sched.mask):
            c = cause[avail_idx[i]]
            if c:
                arrived[i] = False
                cause_counts[c] += 1

        # sanitize arrived uploads (NaN/Inf guard + norm clip)
        overrides = self._corrupt_overrides(rf, arrived, avail_idx, deltas)
        san = sanitize_updates(deltas, np.flatnonzero(arrived), overrides,
                               cfg.faults.clip_delta_norm,
                               norms=delta_norms, finite=finite)
        if arrived.any() and (finite is None or overrides):
            self.last_round_host_syncs += 1
        cause_counts["corrupt"] += len(san.dropped_nonfinite)
        upload = np.zeros_like(sched.mask)
        upload[san.kept] = True
        return UploadState(
            upload=upload, mod_deltas=san.deltas,
            cause_counts=cause_counts, arrived=arrived, rf=rf,
            upload_gains=upload_gains,
            num_dropped_nf=len(san.dropped_nonfinite),
            num_clipped=len(san.clipped))

    def _wants_backfill(self, st: UploadState, sched: S.Schedule) -> bool:
        return (self.faults.enabled and self.cfg.faults.backfill
                and int(st.upload.sum()) < sched.num_scheduled)

    def _finalize_weights(self, upload: np.ndarray) -> np.ndarray:
        """Eq. 2 weight row for the fused finalize core: upload_v / |U|
        as f32.  The same values serve as the Eq. 12 centering alphas
        (both are 1/|U| on uploaded rows, 0 elsewhere)."""
        w = np.asarray(upload, np.float64)
        return (w / max(w.sum(), 1.0)).astype(np.float32)

    def _apply_mods(self, dev_params, deltas, st: UploadState):
        """Scatter sanitizer replacements (clipped / corrupted-but-kept
        uploads) into the stacked [V, ...] trees — one batched scatter
        per leaf; no-op (bitwise) on clean rounds."""
        mod = {i: d for i, d in st.mod_deltas.items() if st.upload[i]}
        if not mod:
            return dev_params, deltas
        idx = np.fromiter(mod.keys(), dtype=np.int64)
        repl = jax.tree.map(lambda *xs: jnp.stack(xs), *mod.values())
        dev_up = set_devices(dev_params, idx,
                             jax.tree.map(lambda p, d: p[None] + d,
                                          self.params, repl))
        return dev_up, set_devices(deltas, idx, repl)

    def _finalize_host(self, j: int, prep: RoundPrep, sched: S.Schedule,
                       st: UploadState, norms, dev_losses) -> Dict:
        """Host half of finalize: Eq. 12 G refresh from the device-side
        deviation norms (``norms``, [V] f32 — rows with no upload are
        garbage and never read), zero-upload degradation, and the round
        record.  The params update already happened in the fused
        finalize core."""
        cfg = self.cfg
        avail_idx = prep.avail_idx
        upload = st.upload
        g_errs = 0
        if upload.any():
            up = np.flatnonzero(upload)
            alphas = np.ones(len(up)) / len(up)
            try:
                g = E.g_hat(None, alphas, prep.p_sampled[up],
                            self.global_dist, norms=norms[up])
                if np.isfinite(g) and g > 0:
                    self.g_hat = g
                if self.single_class:
                    self.g_hat_c = E.g_hat_per_class(
                        None, alphas,
                        self.device_class[avail_idx][up],
                        prep.p_sampled[up], self.global_dist,
                        self.num_classes, norms=norms[up])
            except (ValueError, FloatingPointError, ZeroDivisionError):
                g_errs += 1
                self.g_refresh_errors += 1
        elif self.faults.enabled:
            # zero uploads landed: keep the previous params and decay the
            # estimates toward their priors instead of freezing them
            d = cfg.faults.estimate_decay
            self.sigma_hat = d * self.sigma_hat + (1 - d) * cfg.sigma_init
            self.g_hat = d * self.g_hat + (1 - d) * cfg.g_init
            self.g_hat_c = d * self.g_hat_c + (1 - d) * cfg.g_init

        num_attempted = sched.num_scheduled + st.num_bf_scheduled
        rec = {
            "round": j,
            "num_available": int(prep.avail.sum()),
            "num_scheduled": int(sched.num_scheduled),
            "wemd": float(sched.wemd),
            "sampling_variance": float(sched.sampling_variance),
            "objective": float(sched.objective),
            "sigma_hat": float(self.sigma_hat),
            "g_hat": float(self.g_hat),
            "mean_local_loss": float(dev_losses.mean()),
            # failure telemetry (the fault layer as observability layer)
            "num_uploaded": int(upload.sum()),
            "num_failed": int(num_attempted - upload.sum()),
            "failure_causes": st.cause_counts,
            "num_backfilled": int(st.num_backfilled),
            "num_sanitized": int(st.num_dropped_nf + st.num_clipped),
            "num_clipped": int(st.num_clipped),
            "num_infeasible": int((prep.bstar[avail_idx] < 0).sum()),
            # THIS round's Eq. 12 refresh failures; the trainer
            # attribute ``g_refresh_errors`` is the cumulative total.
            "g_refresh_errors_round": int(g_errs),
            # deprecated alias of g_refresh_errors_round (same value;
            # kept one release for readers of the old ambiguous key)
            "g_refresh_errors": int(g_errs),
        }
        if cfg.eval_every and (j % cfg.eval_every == 0):
            rec["test_accuracy"] = self.evaluate()
        self.history.append(rec)
        return rec

    def _finalize_round(self, j: int, prep: RoundPrep, sched: S.Schedule,
                        st: UploadState, dev_params, deltas,
                        dev_losses: np.ndarray) -> Dict:
        """Eq. 2 aggregation over the uploads that landed + the Eq. 12
        deviation norms in ONE fused dispatch (cell axis of 1), then the
        host half (G refresh, degradation, round record)."""
        dev_up, deltas_eff = self._apply_mods(dev_params, deltas, st)
        w = self._finalize_weights(st.upload)
        active = bool(st.upload.any())
        newp_c, norms_c = self._finalize_core(
            jax.tree.map(lambda x: x[None], self.params),
            jax.tree.map(lambda x: x[None], dev_up),
            jax.tree.map(lambda x: x[None], deltas_eff),
            w[None], np.array([active]))
        self.params = jax.tree.map(lambda x: x[0], newp_c)
        norms = None
        if active:       # the only device->host pull of finalize
            norms = jax.device_get(norms_c)[0]
            self.last_round_host_syncs += 1
        return self._finalize_host(j, prep, sched, st, norms, dev_losses)

    # ------------------------------------------------------------------
    def run_round(self, j: int) -> Dict:
        obs = self.obs
        with obs.span("round"):
            rec = self._run_round_phases(j)
        if obs.enabled:
            self._emit_round_obs(rec)
        return rec

    def _run_round_phases(self, j: int) -> Dict:
        """One round, each phase under an ``obs`` span (spans are the
        no-op singleton when observability is off — the body is the
        pre-instrumentation round loop, statement for statement)."""
        obs = self.obs
        with obs.span("prep"):
            prep = self._prepare_round(j)
        self.last_round_host_syncs = 0

        # fused round core: local update + sigma + deltas + norms +
        # NaN/Inf flags in one XLA program (cell axis of 1), one host
        # sync for all of it
        with obs.span("core"):
            dev_params_c, losses_c, sigma_c, deltas_c, norms_c, fin_c = \
                self._round_core(
                    jax.tree.map(lambda x: x[None], self.params),
                    jax.tree.map(lambda x: x[None], prep.batches),
                    jnp.stack([prep.subkey]))
            lh, sh, nh, fh = jax.device_get((losses_c, sigma_c, norms_c,
                                             fin_c))
            dev_losses, sigma_v, delta_norms = (
                np.asarray(x[0], dtype=np.float64) for x in (lh, sh, nh))
            finite = np.asarray(fh[0])
            self.last_round_host_syncs += 1
            dev_params = jax.tree.map(lambda x: x[0], dev_params_c)
            deltas = jax.tree.map(lambda x: x[0], deltas_c)

        with obs.span("schedule"):
            self._post_core(prep, dev_losses, sigma_v)
            prob = self._make_problem(prep)
            sched = self._schedule(prob, prep.avail_idx, prep.gains,
                                   delta_norms, j)

        with obs.span("upload"):
            st = self._upload_phase(j, prep, sched, deltas, delta_norms,
                                    finite=finite)
            if self._wants_backfill(st, sched):
                prob_bf = self._backfill_problem(prob, sched, st, prep)
                if prob_bf is not None:
                    bf = self._schedule(prob_bf, prep.avail_idx,
                                        st.upload_gains, delta_norms, j)
                    self._apply_backfill(bf, st, prep, deltas,
                                         delta_norms, finite=finite)
        with obs.span("finalize"):
            rec = self._finalize_round(j, prep, sched, st, dev_params,
                                       deltas, dev_losses)
        self._obs_sched_iters = int(sched.iterations)
        return rec

    def _emit_round_obs(self, rec: Dict) -> None:
        """Mirror one round record into the metrics registry and the
        sinks (host-side only — runs after the round's device work, so
        it adds zero device->host syncs)."""
        m = self.obs.metrics
        m.counter("fl.rounds_total").inc()
        hs = self.last_round_host_syncs
        m.counter("fl.host_syncs_total").inc(hs)
        m.gauge("fl.round.host_syncs").set(hs)
        m.counter("fl.uploads_total").inc(rec["num_uploaded"])
        upload_bytes = rec["num_uploaded"] * self.payload / 8.0
        m.counter("fl.upload_bytes_total").inc(upload_bytes)
        m.gauge("fl.round.upload_bytes").set(upload_bytes)
        for cause, n in rec["failure_causes"].items():
            if n:
                m.counter(f"fl.failures.{cause}").inc(n)
        m.counter("fl.sanitized_total").inc(rec["num_sanitized"])
        m.counter("fl.clipped_total").inc(rec["num_clipped"])
        m.counter("fl.backfilled_total").inc(rec["num_backfilled"])
        m.counter("fl.g_refresh_errors_total").inc(
            rec["g_refresh_errors_round"])
        for g in ("sigma_hat", "g_hat", "wemd", "objective"):
            m.gauge(f"fl.{g}").set(rec[g])
        from repro.obs import COUNT_BUCKETS
        m.histogram("sched.iterations", COUNT_BUCKETS).observe(
            self._obs_sched_iters)
        self.obs.round_record(dict(
            rec, host_syncs=hs, upload_bytes=upload_bytes,
            sched_iterations=self._obs_sched_iters))

    # ------------------------------------------------------------------
    def evaluate(self, max_batches: int = 20, batch_size: int = 256) -> float:
        correct = total = 0
        for i in range(0, min(len(self.test), max_batches * batch_size),
                       batch_size):
            x = self.test.inputs[i:i + batch_size]
            y = self.test.labels[i:i + batch_size]
            batch = self.make_batch(x, y)
            if not isinstance(self.model.cfg, CNNConfig):
                pred = np.asarray(self._eval_batch(self.params, batch))
                # token models: accuracy over next-token is meaningless for
                # classification; use the class of the final target token
                correct += (pred == np.asarray(batch["targets"][:, -1])).sum()
            else:
                pred = np.asarray(self._eval_batch(self.params, batch))
                correct += (pred == y).sum()
            total += len(y)
        return float(correct) / max(total, 1)

    def run(self, num_rounds: int, verbose: bool = False) -> List[Dict]:
        for j in range(num_rounds):
            rec = self.run_round(j)
            if verbose and ("test_accuracy" in rec):
                print(f"round {j:4d} sched={rec['num_scheduled']:3d} "
                      f"wemd={rec['wemd']:.3f} acc={rec['test_accuracy']:.3f}")
        return self.history


def _cnn_features_logits(params, cfg, images):
    """Penultimate features + logits for the paper CNN / ResNet18-GN."""
    from repro.models import cnn as C
    if cfg.kind == "paper_cnn":
        x = jax.nn.relu(C._conv(images, params["c1"]))
        x = jax.nn.relu(C._conv(x, params["c2"]))
        x = C._maxpool2(x)
        x = jax.nn.relu(C._conv(x, params["c3"]))
        x = jax.nn.relu(C._conv(x, params["c4"]))
        x = C._maxpool2(x)
        x = x.reshape(x.shape[0], -1)
        feats = jax.nn.relu(x @ params["fc1"] + params["b1"])
        return feats, feats @ params["fc2"] + params["b2"]
    x = jax.nn.relu(C._gn(C._conv(images, params["stem"]), params["gn_s"],
                          params["gn_b"], cfg.gn_groups))
    for si, (cout, stride) in enumerate(C.STAGES):
        for bi in range(2):
            x = C._block_fwd(params[f"s{si}b{bi}"], x,
                             stride if bi == 0 else 1, cfg.gn_groups)
    feats = x.mean(axis=(1, 2))
    return feats, feats @ params["fc"] + params["fc_b"]
