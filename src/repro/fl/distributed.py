"""Distributed federated training step (DESIGN.md §3: pod axis = FL silo).

For tau = 1 (the paper's primary regime, Fig. 4a) a FedCGD round is

    w' = sum_s alpha_s (w - eta grad f_s(w)) = w - eta sum_s alpha_s grad f_s(w)

i.e. one SGD step on the *schedule-weighted* loss.  So the compiled
multi-pod artifact is a single jitted ``fed_train_step`` whose per-example
loss weights carry alpha_v * x_v: the schedule changes round to round, the
executable never does.  Unscheduled silos get weight 0 — the TPU-idiomatic
analogue of "the device does not transmit" (DESIGN.md §3).

``make_train_step`` builds the per-arch step used by the dry-run
(single-pod: plain SGD LM step; multi-pod: weighted federated step), and
``make_serve_step`` the decode step.  Both consume ShapeDtypeStructs only.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.sharding import ShardingCtx


def make_train_step(cfg: ModelConfig, ctx: Optional[ShardingCtx],
                    eta: float = 0.1, federated: bool = False):
    """Returns train_step(params, batch) -> (params, metrics).

    batch: tokens/targets [B, S] (+ modality extras); when ``federated``,
    batch['schedule_weights'] [B] carries alpha_v * x_v per example
    (examples of silo s all share weight alpha_s).
    """

    def train_step(params, batch):
        def loss(p):
            lm_batch = dict(batch)
            if federated:
                w = batch["schedule_weights"].astype(jnp.float32)
                base = lm_batch.get("loss_mask")
                S = batch["targets"].shape[1]
                m = w[:, None] * (base if base is not None
                                  else jnp.ones((w.shape[0], S), jnp.float32))
                lm_batch["loss_mask"] = m
                lm_batch.pop("schedule_weights", None)
            return T.loss_fn(p, cfg, lm_batch, ctx)

        (total, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        new_params = jax.tree.map(
            lambda p, g: p - eta * g.astype(p.dtype), params, grads)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        metrics = dict(metrics, loss=total, grad_norm=gnorm)
        return new_params, metrics

    return train_step


def make_serve_step(cfg: ModelConfig, ctx: Optional[ShardingCtx]):
    def serve_step(params, cache, batch):
        logits, new_cache = T.serve_step(params, cfg, cache, batch, ctx)
        return logits, new_cache
    return serve_step


def make_prefill_step(cfg: ModelConfig, ctx: Optional[ShardingCtx],
                      cache_len: int):
    def prefill_step(params, batch):
        logits, _, cache = T.forward(params, cfg, batch, ctx,
                                     collect_cache=True, cache_len=cache_len)
        return logits[:, -1:], cache
    return prefill_step


def silo_weights(schedule_mask, n_silos: int):
    """alpha_v * x_v (Eq. 2) for the weighted federated step: equal
    dataset sizes => alpha = mask / sum(mask)."""
    m = jnp.asarray(schedule_mask, jnp.float32)
    return m / jnp.maximum(m.sum(), 1.0) * n_silos
    # (scaled by n_silos so that an all-ones schedule reproduces the plain
    #  unweighted mean loss exactly)
