from repro.fl.rounds import FederatedTrainer, FLConfig  # noqa: F401
from repro.fl.client import make_local_update, payload_bits  # noqa: F401
from repro.fl.server import aggregate  # noqa: F401
from repro.faults import FaultConfig, FaultInjector  # noqa: F401
