from repro.fl.rounds import FederatedTrainer, FLConfig  # noqa: F401
from repro.fl.multicell import MultiCellTrainer  # noqa: F401
from repro.fl.client import (make_local_update, make_round_core,  # noqa: F401
                             payload_bits)
from repro.fl.server import aggregate  # noqa: F401
from repro.faults import FaultConfig, FaultInjector  # noqa: F401
