"""Virtual centralized model v^{(j)} (paper Section IV).

    v^{(j)} = w^{(j-1)} - eta * tau * grad F(w^{(j-1)})

grad F is approximated with a large pooled batch from the union of the
participating devices' data (the best available surrogate for the global
dataset).  fc_difference(w, v) then measures U_j, making Proposition 1 /
Theorem 1 empirically checkable (tests + benchmarks do exactly that).
"""
from __future__ import annotations

import jax

from repro.core.cgd import fc_difference  # noqa: F401  (re-export)


def virtual_step(loss_fn, params, global_batch, eta: float, tau: int,
                 rng=None):
    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, global_batch, rng)
    v = jax.tree.map(lambda p, g: p - eta * tau * g.astype(p.dtype),
                     params, grads)
    return v, grads, loss
