"""Multi-cell round engine: C independent cells per aggregation step.

The paper evaluates P1 per cell per round; a deployment runs many cells
concurrently (one edge server each).  ``MultiCellTrainer`` simulates C
independent ``FederatedTrainer`` cells — separate seeds, channel
geometries, model replicas, fault streams — but drives every round
through

  * ONE vmapped local-update program: the fused round core from
    ``repro.fl.client.make_round_core`` with leading axes
    [cell, device, tau] computes all cells' local SGD, Eq. 10 sigmas,
    deltas and delta norms in a single XLA dispatch + one host sync;
  * ONE ``solve_many`` scheduling dispatch: the C per-cell P1 instances
    are padded to a common device count and solved as a single batch by
    the PR 6 engine (jax backend; the f32 Pallas wemd kernels route in
    on TPU backends via ``FLConfig.scheduler_pallas``).

Cells are *padded, not truncated*: a cell with fewer available devices
than the round's max repeats its first device's batch (sliced off after
the core) and pads its P1 instance with zero-distribution, infeasible
(``min_bw = -1``) device rows the solver can never schedule.  With
``num_cells = 1`` nothing is padded and every dispatch is the same
program ``FederatedTrainer`` runs, so the single-cell history is
reproduced bitwise (asserted in tests for both scheduler backends).

Faulty rounds may issue one extra batched ``solve_many`` for the cells
that back-fill failed uploads; fault-free rounds make exactly one
scheduling dispatch (``solve_many_calls`` counts them).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduling as S
from repro.data.datasets import ArrayDataset
from repro.fl.rounds import FederatedTrainer, FLConfig
from repro.models.registry import Model

# schedulers with a batched solve_many implementation
MULTICELL_SCHEDULERS = ("fedcgd-fscd", "fedcgd-gs", "fedcgd-fscd-gc")


def _pad_batches(batches, pad: int):
    """Grow the device axis by ``pad`` rows repeating device 0 (the rows
    are computed and discarded; repeating a real batch keeps the padded
    lanes numerically tame)."""
    if pad == 0:
        return batches
    return jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])], axis=0),
        batches)


def _pad_problems(probs: Sequence[S.Problem]) -> List[S.Problem]:
    """Pad P1 instances to a common device count with zero-distribution,
    infeasible rows (min_bw = -1): the solvers can never schedule them,
    and real-device decisions are unchanged (candidate values are
    computed per device; infeasible rows rank as +inf)."""
    vmax = max(p.num_devices for p in probs)
    out = []
    for p in probs:
        pad = vmax - p.num_devices
        if pad == 0:
            out.append(p)
            continue
        out.append(dataclasses.replace(
            p,
            p_dev=np.concatenate(
                [np.asarray(p.p_dev),
                 np.zeros((pad, np.asarray(p.p_dev).shape[1]))]),
            min_bw=np.concatenate(
                [np.asarray(p.min_bw, np.float64), np.full(pad, -1.0)])))
    return out


def _slice_schedule(sched: S.Schedule, n: int) -> S.Schedule:
    """Drop the padded device rows from a batched solve (they are never
    scheduled, so the counts/objective are unaffected)."""
    if len(sched.mask) == n:
        return sched
    return dataclasses.replace(sched, mask=sched.mask[:n])


class MultiCellTrainer:
    """C FederatedTrainer cells advanced in lock-step, one fused XLA
    round core + one batched scheduling dispatch per aggregation step."""

    def __init__(self, model: Model, train: ArrayDataset,
                 test: ArrayDataset, device_indices, cfg: FLConfig,
                 cell_seeds: Optional[Sequence[int]] = None):
        if cfg.scheduler not in MULTICELL_SCHEDULERS:
            raise ValueError(
                f"MultiCellTrainer requires a batched scheduler "
                f"{MULTICELL_SCHEDULERS}, got {cfg.scheduler!r}")
        C = cfg.num_cells
        if C < 1:
            raise ValueError(f"num_cells must be >= 1, got {C}")
        if cell_seeds is None:
            cell_seeds = [cfg.seed + c for c in range(C)]
        if len(cell_seeds) != C:
            raise ValueError(f"need {C} cell seeds, got {len(cell_seeds)}")
        # one shared device partition, or one partition per cell
        per_cell = (isinstance(device_indices, (list, tuple))
                    and len(device_indices) == C
                    and isinstance(device_indices[0], (list, tuple)))
        parts = (list(device_indices) if per_cell
                 else [device_indices] * C)

        self.cfg = cfg
        self.cells: List[FederatedTrainer] = [
            FederatedTrainer(model, train, test, parts[c],
                             dataclasses.replace(cfg, seed=cell_seeds[c]))
            for c in range(C)]
        # every cell runs the same architecture: share cell 0's compiled
        # round core so C=1 executes the exact program FederatedTrainer
        # runs (bitwise parity) and C>1 reuses one compilation; the
        # per-trainer jitted finalize helpers are shared for the same
        # reason (C standalone trainers would compile C identical copies)
        self._core = self.cells[0]._round_core
        for cell in self.cells[1:]:
            cell._round_core = self.cells[0]._round_core
            cell._sigma_all = self.cells[0]._sigma_all
            cell._agg_core = self.cells[0]._agg_core
            cell._grads_core = self.cells[0]._grads_core
        # one dispatch returning every cell's slice of the stacked core
        # outputs (vs. an eager per-cell-per-leaf slice loop): the rows
        # are NOT trimmed to the cell's device count — padded rows carry
        # zero aggregation weight and are never indexed by the upload /
        # backfill phases, and at C=1 nothing is padded to begin with
        self._unstack = jax.jit(lambda t: tuple(
            jax.tree.map(lambda x, c=c: x[c], t) for c in range(C)))
        self._algorithm = "gs" if cfg.scheduler == "fedcgd-gs" else "fscd"
        self.solve_many_calls = 0        # scheduling dispatches issued
        self.history: List[List[Dict]] = []

    @property
    def num_cells(self) -> int:
        return len(self.cells)

    # ------------------------------------------------------------------
    def _solve_batch(self, probs: Sequence[S.Problem]) -> List[S.Schedule]:
        cfg = self.cfg
        self.solve_many_calls += 1
        return S.solve_many(_pad_problems(probs), self._algorithm,
                            backend=cfg.scheduler_backend,
                            pallas=cfg.scheduler_pallas)

    def run_round(self, j: int) -> List[Dict]:
        cells = self.cells

        # host-side prep per cell (availability, channel, batches) — the
        # per-cell numpy RNG streams stay identical to standalone cells
        preps = [cell._prepare_round(j) for cell in cells]
        n_av = [len(p.avail_idx) for p in preps]
        vmax = max(n_av)
        for cell in cells:
            cell.last_round_host_syncs = 0

        # ONE fused core dispatch: [C, Vmax, ...] local update + sigma +
        # deltas + norms, then one host pull for every scheduling input
        params_c = jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[cell.params for cell in cells])
        batches_c = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_pad_batches(p.batches, vmax - n) for p, n in zip(preps,
                                                                n_av)])
        keys_c = jnp.stack([p.subkey for p in preps])
        dev_params_c, losses_c, sigma_c, deltas_c, norms_c = \
            self._core(params_c, batches_c, keys_c)
        lh, sh, nh = jax.device_get((losses_c, sigma_c, norms_c))

        unstacked = self._unstack((dev_params_c, deltas_c))
        probs, per_cell = [], []
        for c, (cell, prep, n) in enumerate(zip(cells, preps, n_av)):
            cell.last_round_host_syncs += 1
            dev_losses = np.asarray(lh[c, :n], dtype=np.float64)
            sigma_v = np.asarray(sh[c, :n], dtype=np.float64)
            delta_norms = np.asarray(nh[c, :n], dtype=np.float64)
            dev_params, deltas = unstacked[c]
            cell._post_core(prep, dev_losses, sigma_v)
            probs.append(cell._make_problem(prep))
            per_cell.append((dev_losses, delta_norms, dev_params, deltas))

        # ONE scheduling dispatch for all C cells
        scheds = [_slice_schedule(s, n)
                  for s, n in zip(self._solve_batch(probs), n_av)]

        # upload phase per cell; collect the cells that want a backfill
        states, bf_idx, bf_probs = [], [], []
        for c, (cell, prep, sched) in enumerate(zip(cells, preps, scheds)):
            _, delta_norms, _, deltas = per_cell[c]
            st = cell._upload_phase(j, prep, sched, deltas, delta_norms)
            states.append(st)
            if cell._wants_backfill(st, sched):
                pb = cell._backfill_problem(probs[c], sched, st, prep)
                if pb is not None:
                    bf_idx.append(c)
                    bf_probs.append(pb)

        # at most one extra batched dispatch for the backfilling cells
        if bf_probs:
            for c, bf in zip(bf_idx, self._solve_batch(bf_probs)):
                _, delta_norms, _, deltas = per_cell[c]
                cells[c]._apply_backfill(
                    _slice_schedule(bf, n_av[c]), states[c], preps[c],
                    deltas, delta_norms)

        recs = []
        for c, (cell, prep, sched, st) in enumerate(
                zip(cells, preps, scheds, states)):
            dev_losses, _, dev_params, deltas = per_cell[c]
            pad = vmax - n_av[c]
            if pad:     # match the untrimmed [Vmax] trees: padded rows
                # enter Eq. 2 with weight 0 and are never G-refreshed
                st.upload = np.concatenate(
                    [st.upload, np.zeros(pad, bool)])
            recs.append(cell._finalize_round(j, prep, sched, st,
                                             dev_params, deltas,
                                             dev_losses))
        self.history.append(recs)
        return recs

    # ------------------------------------------------------------------
    def run(self, num_rounds: int, verbose: bool = False) -> List[List[Dict]]:
        for j in range(num_rounds):
            recs = self.run_round(j)
            if verbose and ("test_accuracy" in recs[0]):
                accs = " ".join(f"{r['test_accuracy']:.3f}" for r in recs)
                print(f"round {j:4d} acc per cell: {accs}")
        return self.history
