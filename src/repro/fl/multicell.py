"""Multi-cell round engine: C independent cells per aggregation step.

The paper evaluates P1 per cell per round; a deployment runs many cells
concurrently (one edge server each).  ``MultiCellTrainer`` simulates C
independent ``FederatedTrainer`` cells — separate seeds, channel
geometries, model replicas, fault streams — but every round phase is
*batched across cells*, so a C-cell round costs a **constant number of
host syncs and device dispatches independent of C**:

  * prep: availability / channel / fault draws still come from each
    cell's own RNG stream (bitwise-identical to standalone cells), but
    the channel math (path loss, shadow fold, Eq. 9 bandwidths) runs
    once over stacked [C, V] arrays
    (``repro.wireless.channel.draw_gains_batch``,
    ``repro.faults.FaultInjector.draw_many``);
  * local update: ONE fused round-core dispatch
    (``repro.fl.client.make_round_core``) with leading axes
    [cell, device, tau] computes all cells' local SGD, Eq. 10 sigmas,
    deltas, delta norms and NaN/Inf-guard flags, pulled in a single
    device->host sync; model params stay stacked [C, ...] across rounds
    so nothing is re-stacked per round;
  * scheduling: ONE batched ``solve_many`` dispatch over the C per-cell
    P1 instances, padded to a common device count through a cached pad
    layout (no per-round float64 rebuilds);
  * finalize: ONE fused dispatch (``repro.fl.server.make_finalize_core``)
    runs every cell's Eq. 2 aggregation and Eq. 12 deviation norms with
    the upload masks as a [C, V] weight matrix; zero-upload cells keep
    their previous params through an in-graph select, and one host pull
    of the [C, V] norms feeds all cells' sigma-hat / G-hat refreshes.

Host-sync contract: a fault-free round makes exactly 2 device->host
syncs for the WHOLE C-cell round (core outputs + finalize norms),
counted by ``last_round_host_syncs`` on the trainer (contract <= 3,
independent of C; per-cell counters only tick on fault-path work such
as corrupt-delta screening or backfill sanitization, and evaluation
pulls on ``eval_every`` rounds are not counted).

Cells are *padded, not truncated*: a cell with fewer available devices
than the round's max repeats its first device's batch (ignored after
the core) and pads its P1 instance with zero-distribution, infeasible
(``min_bw = -1``) device rows the solver can never schedule.  With
``num_cells = 1`` nothing is padded and every dispatch is the same
program ``FederatedTrainer`` runs, so the single-cell history is
reproduced bitwise (asserted in tests for both scheduler backends);
with full availability every cell of a C>1 run matches a standalone
trainer bitwise (the cell axes roll via ``lax.map`` on CPU, so the
compiled bodies ARE the single-cell programs).

Faulty rounds may issue one extra batched ``solve_many`` for the cells
that back-fill failed uploads; fault-free rounds make exactly one
scheduling dispatch (``solve_many_calls`` counts them).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduling as S
from repro.core.bandwidth import min_bandwidth
from repro.data.datasets import ArrayDataset
from repro.faults.injector import FaultInjector
from repro.fl.rounds import FederatedTrainer, FLConfig
from repro.models.registry import Model
from repro.obs import ObsConfig
from repro.obs import from_config as obs_from_config
from repro.wireless.channel import draw_gains_batch, received_power_batch

# schedulers with a batched solve_many implementation
MULTICELL_SCHEDULERS = ("fedcgd-fscd", "fedcgd-gs", "fedcgd-fscd-gc")


def _pad_batches(batches, pad: int):
    """Grow the device axis by ``pad`` rows repeating device 0 (the rows
    are computed and discarded; repeating a real batch keeps the padded
    lanes numerically tame)."""
    if pad == 0:
        return batches
    return jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])], axis=0),
        batches)


class _PadCache:
    """Cached ``solve_many`` pad layout.

    P1 instances are padded to a common device count with
    zero-distribution, infeasible (``min_bw = -1``) rows: the solvers
    can never schedule them, and real-device decisions are unchanged
    (candidate values are computed per device; infeasible rows rank as
    +inf).  The pad layout only depends on (batch slot, vmax, classes),
    so instead of rebuilding fresh float64 arrays every round the
    buffers are kept across calls and only rewritten in place."""

    def __init__(self):
        self._bufs = {}

    def pad(self, probs: Sequence[S.Problem]) -> List[S.Problem]:
        vmax = max(p.num_devices for p in probs)
        out = []
        for slot, p in enumerate(probs):
            V = p.num_devices
            if V == vmax:
                out.append(p)
                continue
            p_dev = np.asarray(p.p_dev)
            key = (slot, vmax, p_dev.shape[1])
            bufs = self._bufs.get(key)
            if bufs is None:
                bufs = (np.zeros((vmax, p_dev.shape[1])),
                        np.full(vmax, -1.0))
                self._bufs[key] = bufs
            pb, bb = bufs
            pb[:V] = p_dev
            pb[V:] = 0.0
            bb[:V] = np.asarray(p.min_bw, np.float64)
            bb[V:] = -1.0
            out.append(dataclasses.replace(p, p_dev=pb, min_bw=bb))
        return out


def _pad_problems(probs: Sequence[S.Problem]) -> List[S.Problem]:
    """One-shot padding (uncached) — see ``_PadCache``."""
    return _PadCache().pad(probs)


def _slice_schedule(sched: S.Schedule, n: int) -> S.Schedule:
    """Drop the padded device rows from a batched solve (they are never
    scheduled, so the counts/objective are unaffected)."""
    if len(sched.mask) == n:
        return sched
    return dataclasses.replace(sched, mask=sched.mask[:n])


class MultiCellTrainer:
    """C FederatedTrainer cells advanced in lock-step: one fused XLA
    round core, one batched scheduling dispatch and one fused finalize
    per aggregation step — host syncs constant in C."""

    def __init__(self, model: Model, train: ArrayDataset,
                 test: ArrayDataset, device_indices, cfg: FLConfig,
                 cell_seeds: Optional[Sequence[int]] = None):
        if cfg.scheduler not in MULTICELL_SCHEDULERS:
            raise ValueError(
                f"MultiCellTrainer requires a batched scheduler "
                f"{MULTICELL_SCHEDULERS}, got {cfg.scheduler!r}")
        C = cfg.num_cells
        if C < 1:
            raise ValueError(f"num_cells must be >= 1, got {C}")
        if cell_seeds is None:
            cell_seeds = [cfg.seed + c for c in range(C)]
        if len(cell_seeds) != C:
            raise ValueError(f"need {C} cell seeds, got {len(cell_seeds)}")
        # one shared device partition, or one partition per cell
        per_cell = (isinstance(device_indices, (list, tuple))
                    and len(device_indices) == C
                    and isinstance(device_indices[0], (list, tuple)))
        parts = (list(device_indices) if per_cell
                 else [device_indices] * C)

        self.cfg = cfg
        # the engine owns observability: cells are built silent (their
        # ``obs`` is the no-op facade) so C cells never open C sinks,
        # and the engine-level facade tags spans with the cell count
        self.obs = obs_from_config(cfg.obs)
        cell_cfg = dataclasses.replace(cfg, obs=ObsConfig())
        self.cells: List[FederatedTrainer] = [
            FederatedTrainer(model, train, test, parts[c],
                             dataclasses.replace(cell_cfg,
                                                 seed=cell_seeds[c]))
            for c in range(C)]
        for cell in self.cells:
            cell.faults.obs = self.obs     # injected-fault counters
        # every cell runs the same architecture: share cell 0's compiled
        # round core + finalize core so C=1 executes the exact programs
        # FederatedTrainer runs (bitwise parity) and C>1 reuses one
        # compilation (C standalone trainers would compile C copies)
        self._core = self.obs.instrument_jit("round_core",
                                             self.cells[0]._round_core)
        for cell in self.cells[1:]:
            cell._round_core = self.cells[0]._round_core
            cell._sigma_all = self.cells[0]._sigma_all
            cell._finalize_core = self.cells[0]._finalize_core
        self._finalize_core = self.obs.instrument_jit(
            "finalize_core", self.cells[0]._finalize_core)
        # params stay stacked [C, ...] across rounds (the round core and
        # finalize consume/produce the stack directly); cells get their
        # slices back through one jitted dispatch per round
        self._params_c = jax.tree.map(lambda *xs: jnp.stack(xs),
                                      *[cell.params for cell in self.cells])
        self._unstack_params = jax.jit(lambda t: tuple(
            jax.tree.map(lambda x, c=c: x[c], t) for c in range(C)))
        self._pad_cache = _PadCache()
        self._algorithm = "gs" if cfg.scheduler == "fedcgd-gs" else "fscd"
        self.solve_many_calls = 0        # scheduling dispatches issued
        self.last_round_host_syncs = 0   # device->host pulls for the
        #   WHOLE C-cell round (contract: <= 3 fault-free, const in C)
        self.history: List[List[Dict]] = []

    @property
    def num_cells(self) -> int:
        return len(self.cells)

    # ------------------------------------------------------------------
    def _solve_batch(self, probs: Sequence[S.Problem]) -> List[S.Schedule]:
        cfg = self.cfg
        self.solve_many_calls += 1
        return S.solve_many(self._pad_cache.pad(probs), self._algorithm,
                            backend=cfg.scheduler_backend,
                            pallas=cfg.scheduler_pallas, obs=self.obs)

    def _apply_mods_batched(self, dev_params_c, deltas_c, states):
        """Scatter every cell's sanitizer replacements (clipped /
        corrupted-but-kept uploads) into the stacked [C, V, ...] trees —
        one (cell, device) scatter per leaf; no-op on clean rounds."""
        mods = [(c, i, d) for c, st in enumerate(states)
                for i, d in st.mod_deltas.items() if st.upload[i]]
        if not mods:
            return dev_params_c, deltas_c
        cs = jnp.asarray([m[0] for m in mods])
        vs = jnp.asarray([m[1] for m in mods])
        repl = jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[m[2] for m in mods])
        deltas_c = jax.tree.map(
            lambda s, x: s.at[cs, vs].set(x.astype(s.dtype)),
            deltas_c, repl)
        dev_params_c = jax.tree.map(
            lambda s, p, x: s.at[cs, vs].set((p[cs] + x).astype(s.dtype)),
            dev_params_c, self._params_c, repl)
        return dev_params_c, deltas_c

    def run_round(self, j: int) -> List[Dict]:
        obs = self.obs
        with obs.span("round"):
            recs = self._run_round_phases(j)
        if obs.enabled:
            self._emit_round_obs(j, recs)
        return recs

    def _run_round_phases(self, j: int) -> List[Dict]:
        """One C-cell round, every phase under an engine-level ``obs``
        span tagged with the cell count (no-op singletons when off)."""
        cells = self.cells
        C = len(cells)
        cfg = self.cfg
        obs = self.obs
        self.last_round_host_syncs = 0
        for cell in cells:
            cell.last_round_host_syncs = 0

        # host-side prep: availability / channel / batch draws stay on
        # each cell's own RNG stream (bitwise-identical to standalone
        # cells), the channel math runs once over [C, V] stacks
        with obs.span("prep", cells=C):
            avails = [cell._draw_avail() for cell in cells]
            cell_states = [cell.cell for cell in cells]
            gains_cv = draw_gains_batch(cell_states,
                                        [cell.rng for cell in cells])
            rx_cv = received_power_batch(cell_states, gains_cv)
            noise = np.array([cs.params.noise_psd_w
                              for cs in cell_states])[:, None]
            bstar_cv = min_bandwidth(cells[0].payload, cfg.deadline_s,
                                     rx_cv, noise)
            preps = [cell._prep_from_channel(j, av, ai, gains_cv[c],
                                             bstar_cv[c])
                     for c, (cell, (av, ai))
                     in enumerate(zip(cells, avails))]
            n_av = [len(p.avail_idx) for p in preps]
            vmax = max(n_av)

        # ONE fused core dispatch: [C, Vmax, ...] local update + sigma +
        # deltas + norms + finite flags, then one host pull for every
        # scheduling input (params are already stacked — no per-round
        # re-stack)
        with obs.span("core", cells=C):
            batches_c = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[_pad_batches(p.batches, vmax - n)
                  for p, n in zip(preps, n_av)])
            keys_c = jnp.stack([p.subkey for p in preps])
            dev_params_c, losses_c, sigma_c, deltas_c, norms_c, fin_c = \
                self._core(self._params_c, batches_c, keys_c)
            lh, sh, nh, fh = jax.device_get((losses_c, sigma_c, norms_c,
                                             fin_c))
            self.last_round_host_syncs += 1

        with obs.span("schedule", cells=C):
            probs, losses64, norms64 = [], [], []
            for c, (cell, prep, n) in enumerate(zip(cells, preps, n_av)):
                dev_losses = np.asarray(lh[c, :n], dtype=np.float64)
                losses64.append(dev_losses)
                norms64.append(np.asarray(nh[c, :n], dtype=np.float64))
                cell._post_core(prep, dev_losses,
                                np.asarray(sh[c, :n], dtype=np.float64))
                probs.append(cell._make_problem(prep))

            # ONE scheduling dispatch for all C cells (cached pad layout)
            scheds = [_slice_schedule(s, n)
                      for s, n in zip(self._solve_batch(probs), n_av)]

        # upload phase per cell: fault draws batched, NaN/Inf flags come
        # from the core (no sanitizer round-trips), per-cell delta
        # slices only materialized for fault-bearing configs
        with obs.span("upload", cells=C):
            rfs = FaultInjector.draw_many(
                [cell.faults for cell in cells], j)
            need_deltas = (any(cell.faults.enabled for cell in cells)
                           or cfg.faults.clip_delta_norm > 0)
            deltas_cell = [None] * C
            if need_deltas:
                deltas_cell = [jax.tree.map(lambda x, c=c: x[c], deltas_c)
                               for c in range(C)]
            states, bf_idx, bf_probs = [], [], []
            for c, (cell, prep, sched) in enumerate(zip(cells, preps,
                                                        scheds)):
                st = cell._upload_phase(j, prep, sched, deltas_cell[c],
                                        norms64[c],
                                        finite=fh[c, :n_av[c]],
                                        rf=rfs[c])
                states.append(st)
                if cell._wants_backfill(st, sched):
                    pb = cell._backfill_problem(probs[c], sched, st, prep)
                    if pb is not None:
                        bf_idx.append(c)
                        bf_probs.append(pb)

            # at most one extra batched dispatch for the backfilling cells
            if bf_probs:
                for c, bf in zip(bf_idx, self._solve_batch(bf_probs)):
                    cells[c]._apply_backfill(
                        _slice_schedule(bf, n_av[c]), states[c], preps[c],
                        deltas_cell[c], norms64[c],
                        finite=fh[c, :n_av[c]])

        # ONE fused finalize dispatch: Eq. 2 over the [C, V] upload
        # weight matrix + Eq. 12 deviation norms; zero-upload cells keep
        # their previous params through the in-graph select
        with obs.span("finalize", cells=C):
            w_cv = np.zeros((C, vmax), np.float32)
            active = np.zeros(C, bool)
            for c, (cell, st) in enumerate(zip(cells, states)):
                pad = vmax - n_av[c]
                if pad:     # padded rows enter Eq. 2 with weight 0 and
                    # are never G-refreshed
                    st.upload = np.concatenate(
                        [st.upload, np.zeros(pad, bool)])
                w_cv[c] = cell._finalize_weights(st.upload)
                active[c] = st.upload.any()
            dev_params_c, deltas_c = self._apply_mods_batched(
                dev_params_c, deltas_c, states)
            newp_c, norms_fc = self._finalize_core(
                self._params_c, dev_params_c, deltas_c, w_cv, active)
            self._params_c = newp_c
            cell_params = self._unstack_params(newp_c)
            norms_h = jax.device_get(norms_fc)
            self.last_round_host_syncs += 1

            recs = []
            for c, (cell, prep, sched, st) in enumerate(
                    zip(cells, preps, scheds, states)):
                cell.params = cell_params[c]
                recs.append(cell._finalize_host(j, prep, sched, st,
                                                norms_h[c], losses64[c]))
        self.last_round_host_syncs += sum(
            cell.last_round_host_syncs for cell in cells)
        self.history.append(recs)
        return recs

    def _emit_round_obs(self, j: int, recs: List[Dict]) -> None:
        """Engine-level metrics + one ``multicell_round`` record (phase
        breakdown, host syncs) and one per-cell round record.  The
        host-sync contract (<= 3 fault-free, constant in C) is asserted
        through ``fl.round.host_syncs`` in tests, not an ad-hoc int."""
        m = self.obs.metrics
        C = len(self.cells)
        hs = self.last_round_host_syncs
        m.counter("fl.rounds_total").inc()
        m.counter("fl.host_syncs_total").inc(hs)
        m.gauge("fl.round.host_syncs").set(hs)
        m.gauge("fl.cells").set(C)
        uploads = sum(r["num_uploaded"] for r in recs)
        upload_bytes = uploads * self.cells[0].payload / 8.0
        m.counter("fl.uploads_total").inc(uploads)
        m.counter("fl.upload_bytes_total").inc(upload_bytes)
        m.gauge("fl.round.upload_bytes").set(upload_bytes)
        for rec in recs:
            for cause, n in rec["failure_causes"].items():
                if n:
                    m.counter(f"fl.failures.{cause}").inc(n)
        m.counter("fl.sanitized_total").inc(
            sum(r["num_sanitized"] for r in recs))
        m.counter("fl.clipped_total").inc(
            sum(r["num_clipped"] for r in recs))
        m.counter("fl.backfilled_total").inc(
            sum(r["num_backfilled"] for r in recs))
        m.counter("fl.g_refresh_errors_total").inc(
            sum(r["g_refresh_errors_round"] for r in recs))
        self.obs.round_record({
            "kind": "multicell_round", "round": j, "cells": C,
            "host_syncs": hs, "num_uploaded": uploads,
            "upload_bytes": upload_bytes,
            "solve_many_calls": self.solve_many_calls,
        })
        for c, rec in enumerate(recs):
            self.obs.emit(dict(rec, kind="round", cell=c))

    # ------------------------------------------------------------------
    def run(self, num_rounds: int, verbose: bool = False) -> List[List[Dict]]:
        for j in range(num_rounds):
            recs = self.run_round(j)
            if verbose and ("test_accuracy" in recs[0]):
                accs = " ".join(f"{r['test_accuracy']:.3f}" for r in recs)
                print(f"round {j:4d} acc per cell: {accs}")
        return self.history
