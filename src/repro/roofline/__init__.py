from repro.roofline.analysis import (  # noqa: F401
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    Roofline,
    collective_bytes_from_hlo,
    count_params,
    model_flops,
    roofline_terms,
)
