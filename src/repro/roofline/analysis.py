"""Roofline analysis from compiled dry-run artifacts (brief §Roofline).

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link.

  compute term    = HLO_FLOPs / (chips * peak)
  memory term     = HLO_bytes / (chips * hbm_bw)
  collective term = collective_bytes / (chips * link_bw)

``compiled.cost_analysis()`` reports the per-device (post-SPMD) program,
so chips-totals are per-device values * chips; the formulas above then
cancel back to per-device time — we report exactly the brief's three
terms.  Collective bytes are parsed from the optimized HLO text: the sum
of result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (result bytes ~= bytes crossing links per
device, the standard proxy).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by each collective kind (result-shape sum)."""
    out = {k: 0.0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in COLLECTIVE_OPS:
            # match " = <shape> all-gather(" and async "-start(" forms
            if f" {op}(" not in stripped and f" {op}-start(" not in stripped:
                continue
            eq = stripped.split(" = ", 1)
            if len(eq) != 2:
                continue
            rhs = eq[1]
            total = 0
            # result may be a tuple shape: sum every element shape before
            # the op name
            opidx = rhs.find(op)
            for m in _SHAPE_RE.finditer(rhs[:opidx]):
                if m.group(1) in _DTYPE_BYTES:
                    total += _shape_bytes(m.group(1), m.group(2))
            out[op] += total
            counts[op] += 1
            break
    out["counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0
    flops_ratio: float = 0.0     # MODEL_FLOPS / (HLO_FLOPs * chips)

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float, chips: int,
                   model_flops: float = 0.0) -> Roofline:
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": collective}
    bottleneck = max(terms, key=terms.get)
    total_hlo_flops = flops_per_device * chips
    return Roofline(
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        collective_bytes_per_device=collective_bytes_per_device,
        chips=chips,
        compute_s=compute,
        memory_s=memory,
        collective_s=collective,
        bottleneck=bottleneck,
        model_flops=model_flops,
        flops_ratio=(model_flops / total_hlo_flops
                     if total_hlo_flops else 0.0),
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for training;
# 2 N D for inference forward


def count_params(cfg) -> tuple:
    """(total_params, active_params) from the config (analytic)."""
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    H, KV, hd, dff = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_ff
    from repro.configs.base import (CROSS_ATTN, GLOBAL_ATTN, LOCAL_ATTN,
                                    RECURRENT, RWKV)
    total = V * d   # embedding
    active = V * d
    if not cfg.tie_embeddings:
        total += d * V
        active += d * V
    for i in range(L):
        kind = cfg.layer_kind(i)
        if kind == RWKV:
            tm = 4 * d * H * hd + H * hd * d + d * (5 * 32) + 5 * 32 * d \
                + d * 64 + 64 * d
            cm = d * dff + dff * d + d * d
            total += tm + cm
            active += tm + cm
            continue
        if kind == RECURRENT:
            lru = cfg.lru_width or d
            rec = 2 * d * lru + 2 * lru * lru + lru * d
            mlp = 3 * d * dff
            total += rec + mlp
            active += rec + mlp
            continue
        attn = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        total += attn
        active += attn
        if kind == CROSS_ATTN or not cfg.num_experts:
            mlp = 3 * d * dff
            total += mlp
            active += mlp
        else:
            E, k = cfg.num_experts, cfg.experts_per_token
            total += E * 3 * d * dff + d * E
            active += k * 3 * d * dff + d * E
            if cfg.num_shared_experts:
                sh = 3 * d * (cfg.num_shared_experts * dff)
                total += sh
                active += sh
            if cfg.moe_dense_ff:
                dr = 3 * d * cfg.moe_dense_ff
                total += dr
                active += dr
    return total, active


def model_flops(cfg, shape, kind: str) -> float:
    """6 N_active D for training, 2 N_active D for one forward/decode."""
    _, active = count_params(cfg)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    tokens = shape.global_batch          # one new token each
    return 2.0 * active * tokens
