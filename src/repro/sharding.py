"""Sharding context threaded through model code.

The model zoo is mesh-agnostic: every apply() takes an optional
``ShardingCtx``.  With ``ctx=None`` (CPU smoke tests, single-device FL
simulation) no constraint is emitted and MoE layers run all experts
locally.  Under the production mesh the launcher passes a ctx naming the
mesh axes; models emit ``with_sharding_constraint`` on activations and the
MoE layer runs expert-parallel under ``jax.shard_map``.

Axis convention (see DESIGN.md §3):
  pod   — FL silo axis (multi-pod mesh only): FedCGD aggregation axis
  data  — batch / FSDP axis inside a silo
  model — tensor-parallel axis (heads / d_ff / experts / vocab);
          for architectures whose head counts do not divide the axis
          (tp=False) it instead carries sequence parallelism + param
          storage sharding (ZeRO-3 style)

Spec sentinels understood by ``constrain``:
  "batch"  -> ctx.batch_axes
  "model"  -> ctx.model_axis if ctx.tp else None   (TP dims: heads, d_ff)
  "sp"     -> ctx.model_axis                       (sequence parallelism)
  "seq"    -> ctx.seq_axes                         (decode KV-cache length)
  "fsdp"   -> ctx.fsdp_axes
Axes whose size does not divide the dim are dropped automatically, so the
same model code works for reduced smoke configs and 512-chip dry-runs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Optional[Mesh]
    batch_axes: Tuple[str, ...] = ("data",)   # activations' batch dim
    model_axis: Optional[str] = "model"       # tensor parallel axis
    fsdp_axes: Tuple[str, ...] = ()           # param sharding for big tables
    seq_axes: Tuple[str, ...] = ()            # long-context KV cache axis
    tp: bool = True                           # Megatron TP (heads divide)
    # §Perf opt: odd-head archs replicate attention weights (token-
    # parallel attention projections, zero collectives) and col/row-shard
    # the MLP over 'model', instead of ZeRO-3 gathering every layer
    hybrid: bool = False

    @property
    def model_axis_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    def axis_size(self, axes: Tuple[str, ...]) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def resolve(self, s):
        if s == "batch":
            return self.batch_axes
        if s == "model":
            return self.model_axis if self.tp else None
        if s == "sp":
            return self.model_axis
        if s == "seq":
            return self.seq_axes
        if s == "fsdp":
            return self.fsdp_axes
        return s


def constrain(x, ctx: Optional[ShardingCtx], *spec):
    """with_sharding_constraint if a mesh is active, else identity."""
    if ctx is None or ctx.mesh is None:
        return x
    resolved = []
    used = set()
    for dim, s in zip(x.shape, spec):
        s = ctx.resolve(s)
        if s is None or s == ():
            resolved.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        # a mesh axis may appear only once per spec: first dim wins
        axes = tuple(a for a in axes if a not in used)
        size = ctx.axis_size(axes)
        if not axes or size == 0 or dim % max(size, 1) != 0:
            resolved.append(None)
        else:
            used.update(axes)
            resolved.append(axes if len(axes) > 1 else axes[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*resolved)))
