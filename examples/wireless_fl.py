"""End-to-end driver (paper Section VI): wireless FL with FedCGD or any
baseline scheduler, TR 38.901 UMi channel, Table I parameters.

  PYTHONPATH=src python examples/wireless_fl.py --scheduler fedcgd-fscd \
      --rounds 40 --devices 32 --classes 10 --imbalance 3

This is the paper's experiment at container scale: CIFAR-10 is replaced
by a synthetic class-structured image set (DESIGN.md §3) — everything
else (channel, Eq. 9 bandwidth, Algorithm 1/2/3, estimators) is the
paper's pipeline.

``--lossy`` turns on the wireless fault model (upload-time shadow
re-draws, outages, dropouts, corrupted deltas) plus the server defenses
(sanitization, norm clipping, one-shot backfill) and prints the failure
telemetry; individual knobs can be overridden, e.g.:

  PYTHONPATH=src python examples/wireless_fl.py --lossy \
      --outage-prob 0.5 --rounds 10 --devices 16

``--cells C`` simulates C independent cells per aggregation step through
the batched multi-cell engine (one fused local-update program + one
``solve_many`` scheduling dispatch per round; FedCGD schedulers only):

  PYTHONPATH=src python examples/wireless_fl.py --cells 4 --rounds 20

``--metrics-out PATH.jsonl`` turns on the observability layer
(``repro.obs``): per-round phase-timing records stream to the JSONL
file and an end-of-run console summary reports p50/p95 phase times and
failure-cause totals:

  PYTHONPATH=src python examples/wireless_fl.py --lossy \
      --metrics-out metrics.jsonl --rounds 10
"""
import argparse

import numpy as np

from repro.configs.paper_cnn import PAPER_CNN_CIFAR10
from repro.data import (apply_imbalance, dirichlet_partition,
                        sort_and_partition, synthetic_image_dataset,
                        train_test_split)
from repro.fl import FederatedTrainer, FLConfig, MultiCellTrainer
from repro.faults import FaultConfig
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="fedcgd-fscd",
                    choices=["fedcgd-fscd", "fedcgd-gs", "fedcgd-fscd-gc",
                             "bc", "bn", "poc", "fcbs", "random"])
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--devices", type=int, default=32)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--partition", default="sort",
                    choices=["sort", "dirichlet"])
    ap.add_argument("--shards", type=int, default=1, help="l")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--imbalance", type=float, default=1.0, help="r")
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--available-prob", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cells", type=int, default=1,
                    help="independent cells per aggregation step "
                         "(multi-cell engine; FedCGD schedulers only)")
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax"],
                    help="P1 scheduling backend (jax = the batched "
                         "solve_many engine)")
    ap.add_argument("--lossy", action="store_true",
                    help="enable the wireless fault model + defenses")
    ap.add_argument("--outage-prob", type=float, default=None)
    ap.add_argument("--dropout-prob", type=float, default=None)
    ap.add_argument("--corrupt-prob", type=float, default=None)
    ap.add_argument("--reshadow-std-db", type=float, default=None)
    ap.add_argument("--clip-delta-norm", type=float, default=None)
    ap.add_argument("--metrics-out", default=None, metavar="PATH.jsonl",
                    help="enable repro.obs and stream per-round metric "
                         "records to this JSONL file")
    args = ap.parse_args()

    ds = synthetic_image_dataset(num_classes=args.classes, num_per_class=120,
                                 image_size=16, seed=args.seed)
    train, test = train_test_split(ds, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    labels = train.labels
    if args.imbalance != 1.0:
        import dataclasses
        idx = apply_imbalance(labels, args.imbalance, rng)
        train = dataclasses.replace(train, inputs=train.inputs[idx],
                                    labels=labels[idx])
    if args.partition == "sort":
        parts = sort_and_partition(train.labels, args.devices, args.shards,
                                   rng)
    else:
        parts = dirichlet_partition(train.labels, args.devices, args.alpha,
                                    rng)

    import dataclasses as dc
    cfg = dc.replace(PAPER_CNN_CIFAR10.reduced(), num_classes=args.classes)
    model = build_model(cfg)
    faults = FaultConfig()
    if args.lossy:
        faults = FaultConfig(outage_prob=0.2, dropout_prob=0.1,
                             deadline_miss_prob=0.05, corrupt_prob=0.1,
                             reshadow_std_db=4.0, outage_slack=0.2,
                             clip_delta_norm=25.0, backfill=True)
    overrides = {k: getattr(args, k) for k in
                 ("outage_prob", "dropout_prob", "corrupt_prob",
                  "reshadow_std_db", "clip_delta_norm")
                 if getattr(args, k) is not None}
    if overrides:
        import dataclasses
        faults = dataclasses.replace(faults, **overrides)

    from repro.obs import ObsConfig
    obs_cfg = ObsConfig(enabled=args.metrics_out is not None,
                        jsonl_path=args.metrics_out)
    fl = FLConfig(num_devices=args.devices,
                  available_prob=args.available_prob, batch_size=16,
                  tau=args.tau, scheduler=args.scheduler,
                  scheduler_backend=args.backend, eval_every=5,
                  seed=args.seed, num_cells=args.cells, faults=faults,
                  obs=obs_cfg)
    if args.cells > 1:
        mc = MultiCellTrainer(model, train, test, parts, fl)
        mc.run(args.rounds, verbose=True)
        engine = mc
        trainer = mc.cells[0]           # report cell 0 below
        hist = trainer.history
        print(f"\n(multi-cell: {args.cells} cells, "
              f"{mc.solve_many_calls} scheduling dispatches over "
              f"{args.rounds} rounds; reporting cell 0)")
    else:
        trainer = FederatedTrainer(model, train, test, parts, fl)
        engine = trainer
        hist = trainer.run(args.rounds, verbose=True)

    accs = [h["test_accuracy"] for h in hist if "test_accuracy" in h]
    scheds = [h["num_scheduled"] for h in hist]
    wemds = [h["wemd"] for h in hist]
    print(f"\n== {args.scheduler} ==")
    print(f"max accuracy      : {max(accs):.3f}")
    print(f"avg scheduled num : {np.mean(scheds):.2f}")
    print(f"avg WEMD          : {np.mean(wemds):.3f}")
    print(f"final sigma-hat   : {trainer.sigma_hat:.3f}  "
          f"G-hat: {trainer.g_hat:.3f}  "
          f"(G/sigma = {trainer.g_hat / max(trainer.sigma_hat, 1e-9):.3f})")

    if faults.injection_enabled:
        causes = {}
        for h in hist:
            for c, n in h["failure_causes"].items():
                causes[c] = causes.get(c, 0) + n
        uploaded = sum(h["num_uploaded"] for h in hist)
        print("\n-- failure telemetry --")
        print(f"uploads landed    : {uploaded} "
              f"({sum(h['num_failed'] for h in hist)} failed)")
        print(f"causes            : " + ", ".join(
            f"{c}={n}" for c, n in sorted(causes.items())))
        print(f"backfilled        : {sum(h['num_backfilled'] for h in hist)}")
        print(f"sanitized deltas  : {sum(h['num_sanitized'] for h in hist)} "
              f"(clipped {sum(h['num_clipped'] for h in hist)})")
        print(f"zero-upload rounds: "
              f"{sum(1 for h in hist if h['num_uploaded'] == 0)}")

    if engine.obs.enabled:
        from repro.obs import format_summary
        engine.obs.close()
        print("\n== observability summary ==")
        print(format_summary(engine.obs.metrics))
        print(f"metrics written to {args.metrics_out}")


if __name__ == "__main__":
    main()
