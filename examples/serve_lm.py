"""Serving demo: prefill + batched KV-cache decode on any assigned
architecture (reduced variant on CPU; the same serve_step the dry-run
compiles at 512-chip scale).

  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b \
      --batch 4 --prompt-len 32 --gen 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.family in ("audio",):
        raise SystemExit("audio decode needs frame embeddings; "
                         "use --arch with a token model")
    params = T.init(jax.random.key(0), cfg)

    B, P = args.batch, args.prompt_len
    prompt = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    batch = {"tokens": prompt}
    if cfg.family == "vlm":
        batch["encoder_embeddings"] = jax.random.normal(
            jax.random.key(2), (B, cfg.num_encoder_tokens, cfg.encoder_dim))

    cache_len = P + args.gen + 8
    prefill = jax.jit(lambda p, b: T.forward(p, cfg, b, collect_cache=True,
                                             cache_len=cache_len))
    decode = jax.jit(lambda p, c, b: T.serve_step(p, cfg, c, b))

    t0 = time.time()
    logits, _, cache = prefill(params, batch)
    print(f"prefill {B}x{P}: {time.time()-t0:.2f}s")

    key = jax.random.key(3)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, {"tokens": tok})
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, logits[:, -1] / args.temperature)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.gen} tokens x {B} seqs in {dt:.2f}s "
          f"({args.gen * B / max(dt, 1e-9):.1f} tok/s on CPU)")
    print("sampled token ids (seq 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
