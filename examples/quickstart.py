"""Quickstart: one FedCGD round, end to end, in ~30 lines of user code.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.paper_cnn import PAPER_CNN_CIFAR10
from repro.data import (sort_and_partition, synthetic_image_dataset,
                        train_test_split)
from repro.fl import FederatedTrainer, FLConfig
from repro.models import build_model

# 1. a synthetic CIFAR-like dataset, sorted-and-partitioned over 16 devices
ds = synthetic_image_dataset(num_classes=10, num_per_class=60, image_size=16)
train, test = train_test_split(ds)
rng = np.random.default_rng(0)
device_data = sort_and_partition(train.labels, 16, 1, rng)

# 2. the paper's CNN (reduced for CPU) + the FedCGD trainer
model = build_model(PAPER_CNN_CIFAR10.reduced())
fl = FLConfig(num_devices=16, available_prob=0.5, batch_size=16,
              scheduler="fedcgd-fscd", eval_every=1)
trainer = FederatedTrainer(model, train, test, device_data, fl)

# 3. run rounds: each round draws the wireless channel, runs local SGD on
#    every available device, solves P1 (WEMD + sampling variance, Lambert-W
#    bandwidth feasible) and aggregates only the scheduled uploads
for j in range(3):
    rec = trainer.run_round(j)
    print(f"round {j}: available={rec['num_available']} "
          f"scheduled={rec['num_scheduled']} wemd={rec['wemd']:.3f} "
          f"sampling_var={rec['sampling_variance']:.3f} "
          f"acc={rec.get('test_accuracy', float('nan')):.3f}")
