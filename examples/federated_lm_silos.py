"""Cross-silo federated LM training with FedCGD scheduling — the
DESIGN.md §3 mapping at miniature scale: silos hold token corpora with
different *token-superclass* distributions; each round FedCGD picks the
silo group minimizing WEMD + sampling variance; the aggregation runs as
ONE weighted train step (the exact program the multi-pod dry-run
AOT-compiles, with silos on the pod axis).

  PYTHONPATH=src python examples/federated_lm_silos.py --arch rwkv6-3b \
      --rounds 20 --silos 8
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Problem, fscd
from repro.data import synthetic_token_dataset
from repro.fl.distributed import make_train_step
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--silos", type=int, default=8)
    ap.add_argument("--per-silo-batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--superclasses", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    C = args.superclasses
    ds = synthetic_token_dataset(cfg.vocab_size, args.seq + 1,
                                 num_classes=C, num_per_class=48)
    rng = np.random.default_rng(0)

    # silo s prefers superclass s mod C (non-IID corpora)
    silo_idx = [np.flatnonzero(ds.labels == (s % C)) for s in range(args.silos)]
    bucket = max(cfg.vocab_size // C, 1)

    def histogram(tokens):
        h = np.bincount(np.minimum(tokens.reshape(-1) // bucket, C - 1),
                        minlength=C)
        return h / h.sum()

    params = T.init(jax.random.key(0), cfg)
    step = jax.jit(make_train_step(cfg, None, eta=0.05, federated=True))
    global_hist = histogram(ds.inputs)

    for j in range(args.rounds):
        # sample each silo's round corpus + its token-superclass histogram
        silo_toks, hists = [], []
        for s in range(args.silos):
            take = rng.choice(silo_idx[s], size=args.per_silo_batch)
            silo_toks.append(ds.inputs[take])
            hists.append(histogram(ds.inputs[take]))
        p_dev = np.stack(hists)

        # FedCGD P1 over silos (uniform bandwidth here: datacenter silos)
        prob = Problem(p_dev=p_dev, global_dist=global_hist,
                       class_weights=np.ones(C), sigma=1.0,
                       batch_size=args.per_silo_batch * args.seq,
                       min_bw=np.ones(args.silos),
                       total_bw=float(args.silos))
        sched = fscd(prob)

        # one weighted federated step (Eq. 2 as per-example loss weights)
        toks = jnp.asarray(np.concatenate(silo_toks))        # [S*b, seq+1]
        w_silo = sched.mask / max(sched.mask.sum(), 1)
        w = jnp.asarray(np.repeat(w_silo * args.silos,
                                  args.per_silo_batch), jnp.float32)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                 "schedule_weights": w}
        params, metrics = step(params, batch)
        if j % 5 == 0:
            print(f"round {j:3d} loss={float(metrics['loss']):.4f} "
                  f"scheduled={sched.num_scheduled}/{args.silos} "
                  f"wemd={sched.wemd:.3f}")
    print("final loss:", float(metrics["loss"]))


if __name__ == "__main__":
    main()
