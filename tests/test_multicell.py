"""MultiCellTrainer: C cells per aggregation step through one fused
round core + one batched scheduling dispatch.

Key contracts:
  * num_cells=1 reproduces the standalone FederatedTrainer bitwise
    (history records AND final params), for both scheduler backends;
  * with full availability (no padding) every cell of a C>1 run is
    bitwise-identical to a standalone trainer with the same seed — the
    cell axis is rolled (lax.map) on CPU, so the compiled body IS the
    single-cell program;
  * exactly one solve_many dispatch per fault-free round;
  * the WHOLE C-cell round makes <= 3 device->host syncs (2 fault-free:
    core pull + finalize norms), independent of C;
  * the accelerator cell axis (vmap) matches the CPU scan axis to f32
    tolerance for both the round core and the finalize core;
  * C=8 is >= 3x faster per aggregation step than 8 sequential
    FederatedTrainer.run_round calls, measured as the wall-clock of a
    from-scratch experiment (construction + compile + rounds — what
    "run 8 cells" actually costs, since every standalone trainer
    recompiles its own round core and finalize helpers).
"""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs.paper_cnn import CNNConfig
from repro.data import (sort_and_partition, synthetic_image_dataset,
                        train_test_split)
from repro.fl import FederatedTrainer, FLConfig, MultiCellTrainer
from repro.models import build_model


@pytest.fixture(scope="module")
def micro_world():
    ds = synthetic_image_dataset(num_classes=2, num_per_class=40,
                                 image_size=8, seed=0)
    train, test = train_test_split(ds, seed=0)
    parts = sort_and_partition(train.labels, 8, 1,
                               np.random.default_rng(0))
    model = build_model(CNNConfig(name="micro-cnn", kind="paper_cnn",
                                  num_classes=2, image_size=8,
                                  dropout=False, width=0.25))
    return model, train, test, parts


def micro_cfg(cells=1, seed=0, backend="jax", avail=1.0, **kw):
    kw.setdefault("scheduler", "fedcgd-fscd")
    return FLConfig(num_devices=8, available_prob=avail, batch_size=2,
                    tau=1, scheduler_backend=backend, eval_every=0,
                    seed=seed, num_cells=cells, **kw)


def params_equal(a, b):
    return all((np.asarray(x) == np.asarray(y)).all()
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# parity


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_c1_bitwise_parity(micro_world, backend):
    model, train, test, parts = micro_world
    cfg = micro_cfg(backend=backend, avail=0.7)
    ref = FederatedTrainer(model, train, test, parts, cfg)
    mc = MultiCellTrainer(model, train, test, parts, cfg)
    for j in range(5):
        rec_ref = ref.run_round(j)
        rec_mc, = mc.run_round(j)
        assert rec_ref == rec_mc
    assert params_equal(ref.params, mc.cells[0].params)


def test_cells_match_standalone_trainers(micro_world):
    # full availability -> no padding -> every cell's rolled-core body is
    # the standalone program, so C=3 must equal 3 standalone runs bitwise
    model, train, test, parts = micro_world
    mc = MultiCellTrainer(model, train, test, parts, micro_cfg(cells=3))
    mc.run(4)
    for c in range(3):
        ref = FederatedTrainer(model, train, test, parts,
                               micro_cfg(seed=c))
        ref.run(4)
        assert [r[c] for r in mc.history] == ref.history
        assert params_equal(ref.params, mc.cells[c].params)


def test_cells_evolve_independently(micro_world):
    model, train, test, parts = micro_world
    mc = MultiCellTrainer(model, train, test, parts, micro_cfg(cells=2))
    mc.run(3)
    # distinct seeds -> distinct channel draws, batches, trajectories
    assert not params_equal(mc.cells[0].params, mc.cells[1].params)
    losses = [[r["mean_local_loss"] for r in recs] for recs in mc.history]
    assert losses[0][0] != losses[0][1]
    # determinism: the same construction replays the same histories
    mc2 = MultiCellTrainer(model, train, test, parts, micro_cfg(cells=2))
    mc2.run(3)
    assert mc2.history == mc.history


def test_padding_with_partial_availability(micro_world):
    # cells draw different availability -> device counts differ -> the
    # batched core/solve run padded; the padded rows must never surface
    model, train, test, parts = micro_world
    mc = MultiCellTrainer(model, train, test, parts,
                          micro_cfg(cells=3, avail=0.5))
    for recs in mc.run(4):
        for rec in recs:
            assert rec["num_scheduled"] <= rec["num_available"]
            assert rec["num_uploaded"] <= rec["num_available"]
            assert np.isfinite(rec["mean_local_loss"])


# ---------------------------------------------------------------------------
# dispatch accounting


def test_one_solve_many_per_round(micro_world, monkeypatch):
    model, train, test, parts = micro_world
    mc = MultiCellTrainer(model, train, test, parts, micro_cfg(cells=4))
    from repro.core import scheduling as S
    calls = []
    real = S.solve_many
    monkeypatch.setattr(S, "solve_many",
                        lambda *a, **k: calls.append(len(a[0])) or
                        real(*a, **k))
    mc.run(3)
    assert mc.solve_many_calls == 3
    assert calls == [4, 4, 4]      # one batched dispatch of C problems


def test_host_sync_budget(micro_world):
    model, train, test, parts = micro_world
    mc = MultiCellTrainer(model, train, test, parts, micro_cfg(cells=2))
    mc.run(2)
    for cell in mc.cells:
        assert cell.last_round_host_syncs <= 3
    ref = FederatedTrainer(model, train, test, parts, micro_cfg())
    ref.run_round(0)
    assert ref.last_round_host_syncs <= 3


def test_trainer_host_syncs_constant_in_c(micro_world):
    """The batched phase engine's core contract: the WHOLE C-cell round
    makes <= 3 device->host syncs (2 fault-free: core pull + finalize
    norms), and the count does not grow with C."""
    model, train, test, parts = micro_world
    syncs = {}
    for C in (2, 4):
        mc = MultiCellTrainer(model, train, test, parts,
                              micro_cfg(cells=C))
        mc.run(2)
        assert mc.last_round_host_syncs <= 3
        syncs[C] = mc.last_round_host_syncs
    assert syncs[2] == syncs[4]


def test_vmap_scan_cell_axis_parity(micro_world):
    """The accelerator path (cell_axis="vmap") must agree with the CPU
    scan path to f32 tolerance — runnable on CPU, no accelerator needed
    (vmap lowers to batched ops everywhere; only the numerics can
    drift, by reassociated f32 reductions)."""
    import jax.numpy as jnp
    from repro.fl.client import make_round_core
    from repro.fl.server import make_finalize_core
    model, train, test, parts = micro_world
    tr = FederatedTrainer(model, train, test, parts, micro_cfg())
    prep = tr._prepare_round(0)
    p2 = jax.tree.map(lambda x: jnp.stack([x, x]), tr.params)
    b2 = jax.tree.map(lambda x: jnp.stack([x, x]), prep.batches)
    k2 = jnp.stack([prep.subkey, prep.subkey])
    outs = {}
    for axis in ("scan", "vmap"):
        core = make_round_core(tr._loss, tr._sigma_one, tr.cfg.eta,
                               tr.cfg.tau, cell_axis=axis)
        outs[axis] = core(p2, b2, k2)
    for a, b in zip(jax.tree.leaves(outs["scan"]),
                    jax.tree.leaves(outs["vmap"])):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   rtol=2e-5, atol=2e-6)

    dev_params_c, _, _, deltas_c, _, _ = outs["scan"]
    V = jax.tree.leaves(deltas_c)[0].shape[1]
    w2 = np.full((2, V), 1.0 / V, np.float32)
    act2 = np.ones(2, bool)
    fouts = {}
    for axis in ("scan", "vmap"):
        fin = make_finalize_core(tr.cfg.tau, tr.cfg.eta, cell_axis=axis)
        fouts[axis] = fin(p2, dev_params_c, deltas_c, w2, act2)
    for a, b in zip(jax.tree.leaves(fouts["scan"]),
                    jax.tree.leaves(fouts["vmap"])):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64),
                                   rtol=2e-5, atol=2e-6)


def test_rejects_unbatchable_scheduler(micro_world):
    model, train, test, parts = micro_world
    with pytest.raises(ValueError, match="batched scheduler"):
        MultiCellTrainer(model, train, test, parts,
                         micro_cfg(cells=2, scheduler="random"))


def test_faulty_rounds_backfill_batched(micro_world):
    from repro.faults.config import FaultConfig
    model, train, test, parts = micro_world
    cfg = micro_cfg(cells=3, faults=FaultConfig(dropout_prob=0.4,
                                                backfill=True))
    mc = MultiCellTrainer(model, train, test, parts, cfg)
    rounds = 4
    mc.run(rounds)
    # at most one extra batched dispatch per round (the backfill pass)
    assert rounds <= mc.solve_many_calls <= 2 * rounds
    fails = sum(r["num_failed"] for recs in mc.history for r in recs)
    assert fails > 0        # the fault stream actually fired


# ---------------------------------------------------------------------------
# performance


def test_c8_multicell_3x_faster(micro_world):
    """C=8 >= 3x faster per aggregation step than 8 sequential
    FederatedTrainer.run_round calls, wall-clock of the from-scratch
    experiment (fresh trainers: construction + compile + R rounds).
    Process-global JAX warmup and module-level caches are paid before
    either arm, so each arm's cost is its own engine: 8 standalone
    trainers compile 8 identical round cores + finalize helpers, the
    multi-cell engine compiles one."""
    model, train, test, parts = micro_world
    C, R = 8, 4
    warm = FederatedTrainer(model, train, test, parts, micro_cfg(seed=99))
    for j in range(2):
        warm.run_round(j)

    t0 = time.perf_counter()
    mc = MultiCellTrainer(model, train, test, parts, micro_cfg(cells=C))
    for j in range(R):
        mc.run_round(j)
    t_mc = time.perf_counter() - t0

    t0 = time.perf_counter()
    seq = [FederatedTrainer(model, train, test, parts, micro_cfg(seed=c))
           for c in range(C)]
    for j in range(R):
        for tr in seq:
            tr.run_round(j)
    t_seq = time.perf_counter() - t0

    assert t_seq >= 3.0 * t_mc, (
        f"multicell C={C}: {t_mc / R * 1e3:.0f} ms/step vs sequential "
        f"{t_seq / R * 1e3:.0f} ms/step "
        f"({t_seq / t_mc:.2f}x, expected >= 3x)")


def test_c8_steady_state_speedup(micro_world):
    """Once everything is compiled, a C=8 aggregation step must still be
    >= 1.6x faster than 8 sequential standalone rounds — the marginal
    round cost, where the batched phase engine's constant host syncs and
    single dispatches per phase are the entire difference (no compile
    amortization in either arm)."""
    model, train, test, parts = micro_world
    C, R = 8, 6
    mc = MultiCellTrainer(model, train, test, parts, micro_cfg(cells=C))
    seq = [FederatedTrainer(model, train, test, parts, micro_cfg(seed=c))
           for c in range(C)]
    for j in range(2):          # compile + warm both arms
        mc.run_round(j)
        for tr in seq:
            tr.run_round(j)

    t0 = time.perf_counter()
    for j in range(2, 2 + R):
        mc.run_round(j)
    t_mc = time.perf_counter() - t0
    t0 = time.perf_counter()
    for j in range(2, 2 + R):
        for tr in seq:
            tr.run_round(j)
    t_seq = time.perf_counter() - t0

    assert t_seq >= 1.6 * t_mc, (
        f"steady C={C}: {t_mc / R * 1e3:.0f} ms/step vs sequential "
        f"{t_seq / R * 1e3:.0f} ms/step "
        f"({t_seq / t_mc:.2f}x, expected >= 1.6x)")
