"""System-level behaviour tests: the paper's qualitative claims hold in
this implementation (miniature versions of the Section VI experiments)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.paper_cnn import PAPER_CNN_CIFAR10
from repro.core import Problem, exhaustive, fscd, greedy_scheduling
from repro.data import (sort_and_partition, synthetic_image_dataset,
                        train_test_split)
from repro.fl import FederatedTrainer, FLConfig
from repro.models import build_model


def test_fig3_solver_quality():
    """Fig. 3: FSCD's relative error << GS's relative error vs exact."""
    rng = np.random.default_rng(0)
    gs_err, fscd_err = [], []
    for _ in range(30):
        V, C = 10, 5
        p_dev = rng.dirichlet(np.ones(C) * 0.4, size=V)
        prob = Problem(
            p_dev=p_dev, global_dist=rng.dirichlet(np.ones(C) * 3),
            class_weights=rng.uniform(0.5, 1.5, C),
            sigma=rng.uniform(0.2, 2.0), batch_size=32,
            min_bw=rng.uniform(0.5, 1.5, V), total_bw=7.0)
        opt = exhaustive(prob).objective
        gs_err.append(greedy_scheduling(prob).objective / opt - 1)
        fscd_err.append(fscd(prob).objective / opt - 1)
    assert np.mean(fscd_err) <= np.mean(gs_err) + 1e-9
    assert np.mean(fscd_err) < 0.05        # paper: 0.19% on its instances
    assert np.mean(gs_err) < 0.30          # paper: 5.16%


def test_scheduled_count_grows_with_alpha():
    """Fig. 8: with more homogeneous devices (large Dirichlet alpha) the
    optimal schedule includes more devices (sampling variance focus)."""
    rng = np.random.default_rng(1)
    counts = {}
    for alpha in (0.1, 50.0):
        sched_sizes = []
        for trial in range(8):
            V, C = 16, 8
            p_dev = rng.dirichlet(np.ones(C) * alpha, size=V)
            prob = Problem(
                p_dev=p_dev, global_dist=np.ones(C) / C,
                class_weights=np.ones(C), sigma=1.0, batch_size=32,
                min_bw=np.ones(V) * 0.5, total_bw=1e9)
            sched_sizes.append(fscd(prob).num_scheduled)
        counts[alpha] = np.mean(sched_sizes)
    assert counts[50.0] > counts[0.1]


def test_wemd_zero_possible_with_single_class_devices():
    """alpha->0 intuition (paper Sec. VI-C3): single-class devices with
    one device per class can reach WEMD = 0 by scheduling one of each."""
    C = 4
    p_dev = np.eye(C)
    prob = Problem(p_dev=p_dev, global_dist=np.ones(C) / C,
                   class_weights=np.ones(C), sigma=0.2, batch_size=32,
                   min_bw=np.ones(C), total_bw=float(C))
    got = exhaustive(prob)
    assert got.wemd < 1e-12
    assert got.num_scheduled == C


@pytest.mark.slow
def test_fedcgd_competitive_under_heterogeneity():
    """Fig. 4/5 analogue (miniature): FedCGD trains to a sane accuracy on
    heavily non-IID devices and is competitive with random scheduling."""
    ds = synthetic_image_dataset(num_classes=4, num_per_class=100,
                                 image_size=16, noise=0.5, seed=3)
    train, test = train_test_split(ds, seed=3)
    cfg = dataclasses.replace(PAPER_CNN_CIFAR10.reduced(), num_classes=4)
    model = build_model(cfg)
    accs = {}
    for sched in ("fedcgd-fscd", "random"):
        rng = np.random.default_rng(7)
        parts = sort_and_partition(train.labels, 10, 1, rng)
        fl = FLConfig(num_devices=10, available_prob=0.8, batch_size=8,
                      tau=1, scheduler=sched, eval_every=0, seed=7)
        tr = FederatedTrainer(model, train, test, parts, fl)
        tr.run(15)
        accs[sched] = max(tr.evaluate(), 1e-3)
    assert accs["fedcgd-fscd"] >= accs["random"] * 0.8, accs
