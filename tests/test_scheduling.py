"""Scheduling-algorithm correctness (paper Section V-B, Fig. 3)."""
import numpy as np
import pytest

from repro.core import scheduling as S
from repro.core import wemd as WE


def random_problem(rng, V=10, C=5, sigma=None, bw_budget=None):
    p_dev = rng.dirichlet(np.ones(C) * 0.5, size=V)
    global_dist = rng.dirichlet(np.ones(C) * 2.0)
    weights = rng.uniform(0.5, 2.0, C)
    min_bw = rng.uniform(0.5, 2.0, V)
    return S.Problem(
        p_dev=p_dev, global_dist=global_dist, class_weights=weights,
        sigma=sigma if sigma is not None else rng.uniform(0.2, 3.0),
        batch_size=32, min_bw=min_bw,
        total_bw=bw_budget if bw_budget is not None else V * 0.8)


@pytest.mark.parametrize("solver,max_rel_err", [
    (S.greedy_scheduling, 0.35), (S.fscd, 0.10), (S.coordinate_descent, 0.25)])
def test_solver_near_optimal(solver, max_rel_err):
    """GS/FSCD/CD stay within a small relative error of the exact optimum
    on average (paper reports GS 5.16%, FSCD 0.19% on its instances)."""
    rng = np.random.default_rng(0)
    errs = []
    for _ in range(25):
        prob = random_problem(rng)
        opt = S.exhaustive(prob)
        got = solver(prob)
        assert prob.bw_ok(got.mask)
        errs.append((got.objective - opt.objective) / opt.objective)
        assert got.objective >= opt.objective - 1e-9
    assert np.mean(errs) < max_rel_err, np.mean(errs)


def test_fscd_beats_or_matches_greedy_on_average():
    rng = np.random.default_rng(1)
    diffs = []
    for _ in range(30):
        prob = random_problem(rng)
        diffs.append(S.fscd(prob).objective
                     - S.greedy_scheduling(prob).objective)
    assert np.mean(diffs) <= 1e-9


def test_collective_beats_individual():
    """Paper Sec. V example: complementary 'bad' devices form the best
    group — exhaustive picks them; their WEMD is 0."""
    p_dev = np.array([[0.51, 0.49], [0.51, 0.49], [0.8, 0.2], [0.2, 0.8]])
    prob = S.Problem(p_dev=p_dev, global_dist=np.array([0.5, 0.5]),
                     class_weights=np.ones(2), sigma=0.01, batch_size=32,
                     min_bw=np.ones(4), total_bw=2.0)
    opt = S.exhaustive(prob)
    assert list(np.flatnonzero(opt.mask)) == [2, 3]
    assert opt.wemd == pytest.approx(0.0, abs=1e-12)


def test_bandwidth_constraint_respected():
    rng = np.random.default_rng(2)
    for _ in range(10):
        prob = random_problem(rng, bw_budget=2.0)
        for solver in (S.greedy_scheduling, S.fscd, S.coordinate_descent,
                       S.random_schedule):
            got = solver(prob)
            assert prob.bw_ok(got.mask)


def test_infeasible_devices_never_scheduled():
    rng = np.random.default_rng(3)
    prob = random_problem(rng)
    prob.min_bw[::2] = -1.0          # Eq. 9 infeasible marker
    for solver in (S.greedy_scheduling, S.fscd, S.coordinate_descent):
        got = solver(prob)
        assert not got.mask[::2].any()


def test_partition_reduction():
    """Lemma 4: a partition instance maps to P1; exhaustive P1 solves it."""
    r = np.array([3, 1, 1, 2, 2, 1])   # partition exists: {3,2} / {1,1,2,1} sums 5,5
    rsum = r.sum()
    C = 1
    s = 2
    # P2 setup: p_{v,0} = r_v, p_0 = rsum/(2s), huge sigma/(sqrt(b) G)
    p_dev = r[:, None].astype(float)
    prob = S.Problem(
        p_dev=p_dev, global_dist=np.array([rsum / (2 * s)]),
        class_weights=np.ones(1), sigma=1e6, batch_size=1,
        min_bw=np.ones(len(r)), total_bw=float(s))
    opt = S.exhaustive(prob)
    assert opt.num_scheduled == s
    chosen = r[opt.mask].sum()
    assert chosen == rsum / 2        # found the equal-sum subset of size 2


def test_best_effort_baselines():
    rng = np.random.default_rng(4)
    prob = random_problem(rng)
    gains = rng.uniform(0, 1, prob.num_devices)
    bc = S.best_channel(prob, gains)
    assert prob.bw_ok(bc.mask)
    # BC schedules a prefix of the best-gain order
    order = np.argsort(-gains)
    sched_ranks = np.flatnonzero(bc.mask[order])
    feas_order = [v for v in order if prob.feasible()[v]]
    k = bc.num_scheduled
    assert set(np.flatnonzero(bc.mask)) == set(feas_order[:k])

    bn = S.best_norm(prob, rng.uniform(0, 1, prob.num_devices))
    poc = S.power_of_choice(prob, rng.uniform(0, 3, prob.num_devices), 6)
    fcbs = S.fed_cbs(prob, np.ones(prob.num_devices), 3)
    for sch in (bn, poc, fcbs):
        assert prob.bw_ok(sch.mask)


def test_fscd_early_exit_matches_full_run():
    """The early-exit rule must not change the result."""
    rng = np.random.default_rng(5)
    for _ in range(10):
        prob = random_problem(rng, V=8, sigma=0.05)
        got = S.fscd(prob)
        opt = S.exhaustive(prob)
        assert got.objective <= opt.objective * 1.2 + 1e-9


def test_schedule_metrics_consistent():
    rng = np.random.default_rng(6)
    prob = random_problem(rng)
    got = S.fscd(prob)
    assert got.objective == pytest.approx(got.wemd + got.sampling_variance)
    assert got.wemd == pytest.approx(
        WE.wemd_of_set(prob.p_dev, got.mask, prob.global_dist,
                       prob.class_weights))
