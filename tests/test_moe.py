"""MoE dispatch correctness (sort-based, capacity-bounded)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as M


def tiny_cfg(**kw):
    base = get_config("qwen3-moe-235b-a22b").reduced()
    return dataclasses.replace(base, **kw)


def dense_reference(params, cfg, x):
    """Compute the exact top-k MoE output with no capacity limit."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ params["router"]
    w, e, _ = M.top_k_routing(logits, cfg.experts_per_token)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        for j in range(cfg.experts_per_token):
            ee = int(e[t, j])
            h = jax.nn.silu(xt[t] @ params["w_gate"][ee]) \
                * (xt[t] @ params["w_up"][ee])
            out[t] += float(w[t, j]) * np.asarray(h @ params["w_down"][ee])
    return out.reshape(B, S, d)


def test_moe_matches_dense_reference_with_ample_capacity():
    cfg = tiny_cfg(capacity_factor=8.0)     # no drops
    key = jax.random.key(0)
    params = M.moe_params_init(key, cfg, jnp.float32)
    params.pop("shared", None)
    params.pop("dense_residual", None)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    y, aux = M.moe_ffn(params, cfg, x, ctx=None)
    ref = dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), ref, atol=2e-4, rtol=1e-3)
    assert float(aux) >= 0


def test_capacity_drops_tokens_not_crash():
    cfg = tiny_cfg(capacity_factor=0.1)
    params = M.moe_params_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y, aux = M.moe_ffn(params, cfg, x, ctx=None)
    assert np.isfinite(np.asarray(y)).all()
    # with tiny capacity the output magnitude shrinks (drops to zero)
    cfg2 = tiny_cfg(capacity_factor=8.0)
    y2, _ = M.moe_ffn(params, cfg2, x, ctx=None)
    assert float(jnp.abs(y).mean()) <= float(jnp.abs(y2).mean()) + 1e-6


def test_positions_in_expert():
    flat = jnp.asarray([2, 0, 2, 1, 2, 0], jnp.int32)
    pos, sizes, order, start = M._positions_in_expert(flat, 3)
    np.testing.assert_array_equal(np.asarray(sizes), [2, 1, 3])
    # arrival ranks within each expert, in original order
    np.testing.assert_array_equal(np.asarray(pos), [0, 0, 1, 0, 2, 1])


def test_top_k_routing_normalized():
    logits = jax.random.normal(jax.random.key(0), (10, 8))
    w, e, p = M.top_k_routing(logits, 3)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-6)
    assert (np.asarray(w) >= 0).all()
    assert np.asarray(e).max() < 8


def test_aux_loss_balanced_lower_than_skewed():
    cfg = tiny_cfg()
    E, k = cfg.num_experts, cfg.experts_per_token
    T = 64
    # balanced: uniform router probs -> aux = router_aux_loss * 1.0
    me = np.ones(E) / E
    ce = np.ones(E) / E
    balanced = E * np.sum(me * ce)
    skew = np.zeros(E)
    skew[0] = 1.0
    skewed = E * np.sum(skew * skew)
    assert balanced < skewed


def test_shared_and_dense_residual_paths():
    cfg = tiny_cfg(num_shared_experts=1, moe_dense_ff=32)
    params = M.moe_params_init(jax.random.key(0), cfg, jnp.float32)
    assert "shared" in params and "dense_residual" in params
    x = jax.random.normal(jax.random.key(1), (1, 4, cfg.d_model))
    y, _ = M.moe_ffn(params, cfg, x, ctx=None)
    assert np.isfinite(np.asarray(y)).all()
