"""Roofline analysis unit tests."""
import numpy as np

from repro.configs import get_config
from repro.launch.shapes import SHAPES
from repro.roofline import (collective_bytes_from_hlo, count_params,
                            model_flops, roofline_terms)


def test_collective_parser():
    hlo = """
  %ag = bf16[8,512,128]{2,1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[1024]{0} all-reduce-start(%y), to_apply=%sum
  %rs = (bf16[64]{0}, bf16[64]{0}) reduce-scatter(%a, %b), dimensions={0}
  %nothing = f32[4]{0} add(%p, %q)
  %cp = u32[16]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["all-gather"] == 8 * 512 * 128 * 2
    assert got["all-reduce"] == 1024 * 4
    assert got["reduce-scatter"] == 64 * 2 * 2
    assert got["collective-permute"] == 16 * 4
    assert got["counts"]["all-gather"] == 1


def test_roofline_bottleneck_selection():
    r = roofline_terms(1e15, 1e9, 1e9, chips=256, model_flops=2.56e17)
    assert r.bottleneck == "compute"
    assert abs(r.flops_ratio - 1.0) < 1e-6
    r2 = roofline_terms(1e9, 1e12, 1e9, chips=256)
    assert r2.bottleneck == "memory"
    r3 = roofline_terms(1e9, 1e9, 1e12, chips=256)
    assert r3.bottleneck == "collective"


def test_count_params_sane():
    # yi-34b should count ~34B params
    total, active = count_params(get_config("yi-34b"))
    assert 30e9 < total < 40e9
    assert total == active
    # qwen3: 235B total, 22B active
    total, active = count_params(get_config("qwen3-moe-235b-a22b"))
    assert 180e9 < total < 260e9
    assert 15e9 < active < 30e9
    # moonshot: the brief's numbers (48L x 64e x d_ff 1408) give ~29B
    # total / ~5B active (the HF card's "16B" elides layer-0-dense and
    # fine-grained expert details; we follow the brief exactly)
    total, active = count_params(get_config("moonshot-v1-16b-a3b"))
    assert 20e9 < total < 32e9
    assert 2e9 < active < 6e9


def test_model_flops_training_vs_decode():
    cfg = get_config("qwen2-7b")
    tr = model_flops(cfg, SHAPES["train_4k"], "train")
    dec = model_flops(cfg, SHAPES["decode_32k"], "decode")
    assert tr > dec * 1e4
    total, _ = count_params(cfg)
    assert abs(tr - 6 * total * 256 * 4096) / tr < 1e-9
