"""Optional-``hypothesis`` shim so the suite collects without the package.

Property-based tests import ``given``/``settings``/``st``/``hnp`` from
here instead of from ``hypothesis`` directly.  When hypothesis is
installed (the dev extra), the real objects are re-exported and the
property tests run as usual.  When it is missing, ``given`` swaps the
test body for a skip and the strategy namespaces collapse to inert
placeholders, so module import — and therefore tier-1 collection —
still succeeds.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    import hypothesis.extra.numpy as hnp
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs any attribute access / call / .map chain at module
        scope; never actually generates data."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()
    hnp = _AnyStrategy()

    def given(*args, **kwargs):
        def decorate(fn):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = getattr(fn, "__name__", "test_property")
            skipped.__doc__ = getattr(fn, "__doc__", None)
            return skipped
        return decorate

    def settings(*args, **kwargs):
        def decorate(fn):
            return fn
        return decorate

__all__ = ["given", "settings", "st", "hnp", "HAVE_HYPOTHESIS"]
