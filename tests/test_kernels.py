"""Per-kernel allclose vs the pure-jnp oracles, sweeping shapes/dtypes
(interpret=True executes the exact TPU kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.persample_gradnorm import persample_gradnorm_pallas
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.rwkv_scan import wkv_pallas
from repro.kernels.wemd_swap import wemd_add_pallas, wemd_swap_pallas

RNG = np.random.default_rng(0)


def randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


@pytest.mark.parametrize("B,H,S,T,hd", [
    (2, 3, 128, 128, 64), (1, 2, 256, 256, 64), (2, 2, 100, 100, 32),
    (1, 2, 64, 192, 64), (1, 1, 128, 128, 128), (1, 1, 257, 257, 64)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32), (False, 0)])
def test_flash_attention_shapes(B, H, S, T, hd, causal, window):
    q, k, v = randn((B, H, S, hd)), randn((B, H, T, hd)), randn((B, H, T, hd))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, expect, atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5),
                                        (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, atol):
    q = randn((1, 2, 128, 64), dtype)
    k = randn((1, 2, 128, 64), dtype)
    v = randn((1, 2, 128, 64), dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=atol, rtol=3e-2)


@pytest.mark.parametrize("B,T,H,hd", [(2, 48, 3, 32), (1, 16, 2, 64),
                                      (2, 100, 2, 16), (1, 64, 1, 64)])
def test_wkv_kernel(B, T, H, hd):
    r = randn((B, T, H, hd))
    k = randn((B, T, H, hd), scale=0.3)
    v = randn((B, T, H, hd))
    w = jnp.asarray(
        jax.nn.sigmoid(RNG.normal(size=(B, T, H, hd)) * 2) * 0.6 + 0.39,
        jnp.float32)
    u = randn((H, hd), scale=0.1)
    y, s = wkv_pallas(r, k, v, w, u, interpret=True)
    yr, sr = ref.wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(y, yr, atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(s, sr, atol=2e-3, rtol=1e-3)


def test_wkv_kernel_extreme_decay():
    """Near-zero decays must not overflow (log-space pairwise products)."""
    B, T, H, hd = 1, 32, 1, 16
    r, k, v = randn((B, T, H, hd)), randn((B, T, H, hd)), randn((B, T, H, hd))
    w = jnp.full((B, T, H, hd), 1e-4, jnp.float32)
    u = randn((H, hd))
    y, s = wkv_pallas(r, k, v, w, u, interpret=True)
    yr, sr = ref.wkv_ref(r, k, v, w, u)
    assert np.isfinite(np.asarray(y)).all()
    np.testing.assert_allclose(y, yr, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("B,T,D", [(2, 300, 64), (1, 128, 512), (3, 37, 100),
                                   (1, 1024, 256)])
def test_rglru_kernel(B, T, D):
    a = jnp.asarray(RNG.uniform(0.8, 0.999, (B, T, D)), jnp.float32)
    b = randn((B, T, D))
    h0 = randn((B, D))
    y, hT = rglru_pallas(a, b, h0, interpret=True)
    yr, hr = ref.rglru_ref(a, b, h0)
    np.testing.assert_allclose(y, yr, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(hT, hr, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,d,C", [(64, 120, 10), (100, 512, 100),
                                   (32, 48, 5), (130, 64, 16)])
def test_persample_gradnorm_kernel(B, d, C):
    h = randn((B, d))
    logits = randn((B, C))
    labels = jnp.asarray(RNG.integers(0, C, B), jnp.int32)
    s, gisq = persample_gradnorm_pallas(h, logits, labels, interpret=True)
    sr, gr = ref.persample_gradnorm_ref(h, logits, labels)
    np.testing.assert_allclose(s, sr, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(gisq, gr, atol=1e-2, rtol=1e-3)


def _wemd_inputs(B, V, C, size):
    p_dev = jnp.asarray(RNG.dirichlet(np.full(C, 0.4), size=(B, V)),
                        jnp.float32)
    p_sum = p_dev[:, :size].sum(axis=1)
    gd = p_dev.mean(axis=1)
    cw = jnp.asarray(RNG.uniform(0.5, 1.5, (B, C)), jnp.float32)
    sizes = jnp.full((B,), float(size), jnp.float32)
    return p_sum, p_dev, gd, cw, sizes


@pytest.mark.parametrize("B,V,C", [(2, 16, 10), (1, 64, 10), (3, 7, 5),
                                   (2, 33, 100), (1, 130, 130)])
def test_wemd_swap_kernel(B, V, C):
    """Tiled [in,out] swap-matrix kernel vs the jnp oracle (class axis
    tiled, V padded to the i-block) — acceptance bar: 1e-5."""
    args = _wemd_inputs(B, V, C, size=min(5, V))
    out = wemd_swap_pallas(*args, interpret=True)
    expect = ref.wemd_swap_ref(*args)
    assert out.shape == (B, V, V)
    np.testing.assert_allclose(out, expect, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,V,C", [(2, 16, 10), (1, 64, 10), (3, 7, 5),
                                   (2, 33, 100)])
def test_wemd_add_kernel(B, V, C):
    args = _wemd_inputs(B, V, C, size=min(3, V))
    out = wemd_add_pallas(*args, interpret=True)
    expect = ref.wemd_add_ref(*args)
    assert out.shape == (B, V)
    np.testing.assert_allclose(out, expect, atol=1e-5, rtol=1e-5)


def test_wemd_kernel_block_sweep():
    """Non-divisible block shapes hit the padding paths."""
    args = _wemd_inputs(2, 19, 11, size=4)
    expect = ref.wemd_swap_ref(*args)
    for bi, bc in [(4, 4), (8, 11), (16, 128)]:
        out = wemd_swap_pallas(*args, block_i=bi, block_c=bc,
                               interpret=True)
        np.testing.assert_allclose(out, expect, atol=1e-5, rtol=1e-5)


def test_model_wkv_matches_kernel():
    """models.rwkv6.wkv_chunked (XLA path) == Pallas kernel == oracle."""
    from repro.models.rwkv6 import wkv_chunked
    B, T, H, hd = 2, 40, 2, 32
    r, k, v = randn((B, T, H, hd)), randn((B, T, H, hd), scale=0.3), \
        randn((B, T, H, hd))
    w = jnp.asarray(jax.nn.sigmoid(RNG.normal(size=(B, T, H, hd))) * 0.5
                    + 0.45, jnp.float32)
    u = randn((H, hd), scale=0.1)
    state = jnp.zeros((B, H, hd, hd), jnp.float32)
    y1, s1 = wkv_chunked(r, k, v, w, u, state)
    y2, s2 = wkv_pallas(r, k, v, w, u, interpret=True)
    np.testing.assert_allclose(y1, y2, atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(s1, s2, atol=2e-3, rtol=1e-3)
