"""Wireless substrate: Eq. 9 bandwidth + TR 38.901 channel."""
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core.bandwidth import (min_bandwidth, min_bandwidth_bisect,
                                  uplink_rate)
from repro.wireless.channel import (ChannelParams, los_probability, make_cell,
                                    path_loss_db)

N0 = 10 ** ((-174 + 6) / 10) * 1e-3   # noise psd + noise figure, W/Hz


@given(st.floats(-13, -7), st.floats(0.1, 10), st.floats(4, 8))
@settings(max_examples=60, deadline=None)
def test_lambertw_matches_bisect(log_sh, deadline, log_bits):
    sh, bits = 10 ** log_sh, 10 ** log_bits
    bw = min_bandwidth(bits, deadline, np.array([sh]), N0)[0]
    ref = min_bandwidth_bisect(bits, deadline, sh, N0)
    if ref < 0:
        assert bw < 0
    else:
        assert abs(bw - ref) / ref < 1e-5


@given(st.floats(-12, -8))
@settings(max_examples=30, deadline=None)
def test_minimum_bandwidth_achieves_rate(log_sh):
    sh = 10 ** log_sh
    bits, deadline = 1e6, 2.0
    bw = min_bandwidth(bits, deadline, np.array([sh]), N0)[0]
    if bw > 0:
        rate = uplink_rate(bw, sh, N0)
        assert rate * deadline >= bits * (1 - 1e-6)
        # strictly minimal: 1% less bandwidth must miss the deadline
        assert uplink_rate(bw * 0.99, sh, N0) * deadline < bits


def test_bandwidth_monotone_in_gain():
    sh = np.logspace(-12, -8, 20)
    bw = min_bandwidth(1e6, 2.0, sh, N0)
    ok = bw[bw > 0]
    assert (np.diff(ok) <= 1e-6).all()   # better channel -> less bandwidth


def test_los_probability_bounds():
    d = np.linspace(1, 1000, 200)
    p = los_probability(d)
    assert ((p >= 0) & (p <= 1)).all()
    assert p[0] == 1.0                     # <=18 m is always LOS
    assert p[-1] < 0.1


def test_path_loss_monotone_distance():
    d = np.linspace(10, 500, 100)
    for los in (np.ones(100, bool), np.zeros(100, bool)):
        pl = path_loss_db(d, 3.5, los)
        assert (np.diff(pl) > 0).all()
    # NLOS always lossier than LOS
    assert (path_loss_db(d, 3.5, np.zeros(100, bool))
            > path_loss_db(d, 3.5, np.ones(100, bool))).all()


def test_cell_generation_and_gains():
    rng = np.random.default_rng(0)
    cell = make_cell(64, rng)
    assert (cell.d2d <= cell.params.cell_radius_m + 1e-9).all()
    gains = cell.draw_gains(rng)
    assert (gains > 0).all() and (gains < 1).all()
    rx = cell.received_power(gains)
    # Table I: 23 dBm tx power
    assert np.isclose(cell.params.tx_power_w, 0.1995, rtol=1e-3)
    assert (rx < cell.params.tx_power_w).all()


def test_paper_deadline_schedules_some_devices():
    """With Table I parameters and a 2 s deadline, a 4-ish MB model is
    uploadable by a reasonable fraction of a 64-device cell."""
    rng = np.random.default_rng(1)
    cell = make_cell(64, rng)
    gains = cell.draw_gains(rng)
    bits = 0.5e6 * 32            # ~0.5M params * 32 bit
    bw = min_bandwidth(bits, 2.0, cell.received_power(gains),
                       cell.params.noise_psd_w)
    feasible = (bw > 0) & (bw <= cell.params.total_bandwidth_hz)
    assert feasible.sum() >= 16
