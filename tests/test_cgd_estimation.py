"""Multi-level CGD instrumentation + parameter estimation (Sec. IV, V-C)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cgd
from repro.core import estimation as E


def grads_from(vectors):
    return [{"w": jnp.asarray(v)} for v in vectors]


def test_collective_vs_individual_divergence():
    """Remark 1 / Fig. 2: two 'bad' complementary devices can have lower
    COLLECTIVE divergence than one 'good' device."""
    gF = {"w": jnp.zeros(4)}
    g1 = {"w": jnp.ones(4) * 0.3}            # small individual divergence
    g2 = {"w": jnp.ones(4) * 2.0}            # big ...
    g3 = {"w": -jnp.ones(4) * 2.0}           # ... but complementary
    d = cgd.individual_divergences([g1, g2, g3], gF)
    assert d[0] < d[1] and d[0] < d[2]
    delta_23 = float(cgd.device_level_cgd([g2, g3], [0.5, 0.5], gF))
    assert delta_23 < d[0]


def test_full_participation_zero_cgd():
    rng = np.random.default_rng(0)
    gs = grads_from(rng.normal(size=(5, 6)))
    alphas = np.ones(5) / 5
    gF = {"w": jnp.mean(jnp.stack([g["w"] for g in gs]), axis=0)}
    assert float(cgd.device_level_cgd(gs, alphas, gF)) < 1e-6


def test_triangle_inequality_on_cgd():
    rng = np.random.default_rng(1)
    gs = grads_from(rng.normal(size=(4, 8)))
    gF = {"w": jnp.asarray(rng.normal(size=8))}
    alphas = np.ones(4) / 4
    coll = float(cgd.device_level_cgd(gs, alphas, gF))
    indiv = cgd.individual_divergences(gs, gF)
    assert coll <= (alphas * indiv).sum() + 1e-6


def test_theorem1_bound_dominates_terms():
    b = cgd.theorem1_bound(delta=0.5, sigma=2.0, num_scheduled=4,
                           batch_size=32, tau=3, eta=0.1, beta=1.0, g=5.0)
    bias = cgd.local_iter_bias_bound(3, 0.1, 1.0, 5.0)
    assert b >= bias
    assert b >= 0.1 * 3 * 0.5


def test_local_iter_bias_zero_for_tau1():
    assert cgd.local_iter_bias_bound(1, 0.1, 1.0, 5.0) == 0.0


# ---------------------------------------------------------------------------
# estimation


def test_sigma_lastlayer_matches_exact_linear():
    d, C, B = 12, 4, 32
    W = jax.random.normal(jax.random.key(0), (d, C)) * 0.2
    h = jax.random.normal(jax.random.key(1), (B, d))
    y = jax.random.randint(jax.random.key(2), (B,), 0, C)

    def loss_per_sample(params, ex):
        hi, yi = ex
        return -jax.nn.log_softmax(hi @ params)[yi]

    exact = float(E.sigma_hat_exact(loss_per_sample, W, (h, y)))
    ll = float(E.sigma_hat_lastlayer(h, h @ W, y))
    assert abs(exact - ll) < 1e-4 * max(exact, 1)


def test_sigma_lastlayer_kernel_path():
    d, C, B = 16, 5, 64
    h = jax.random.normal(jax.random.key(1), (B, d))
    logits = jax.random.normal(jax.random.key(2), (B, C))
    y = jax.random.randint(jax.random.key(3), (B,), 0, C)
    a = float(E.sigma_hat_lastlayer(h, logits, y))
    b = float(E.sigma_hat_lastlayer(h, logits, y, use_kernel=True))
    assert abs(a - b) < 1e-3 * max(a, 1)


def test_sigma_global_aggregation():
    sig = np.array([1.0, 2.0, 3.0])
    alpha = np.ones(3) / 3
    expect = np.sqrt((1 + 4 + 9) / 3)
    assert abs(E.sigma_hat_global(sig, alpha) - expect) < 1e-9


def test_g_hat_recovers_scale():
    """Devices whose gradient offset is proportional to their label-
    distribution L1 distance: G-hat should recover the proportionality."""
    rng = np.random.default_rng(0)
    C = 4
    p_dev = np.eye(C)                       # single-class devices
    gd = np.ones(C) / C
    G_true = 2.5
    base = rng.normal(size=8)
    grads = []
    for v in range(C):
        l1 = np.abs(p_dev[v] - gd).sum()
        direction = np.zeros(8)
        direction[v % 8] = 1.0
        grads.append({"w": jnp.asarray(base + G_true * l1 * direction)})
    alphas = np.ones(C) / C
    ghat = E.g_hat(grads, alphas, p_dev, gd)
    # the estimator measures ||grad_v - mean||/l1 <= G_true (and > 0)
    assert 0.5 * G_true < ghat <= G_true * 1.5


def test_device_grad_estimate():
    old = {"w": jnp.ones(3)}
    new = {"w": jnp.ones(3) - 0.2}
    g = E.device_grad_estimate(new, old, tau=2, eta=0.1)
    np.testing.assert_allclose(g["w"], jnp.ones(3), atol=1e-6)
