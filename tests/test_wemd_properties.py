"""Property-based tests (hypothesis) for the WEMD / P1-objective layer."""
import numpy as np

from _hypothesis_compat import given, hnp, settings, st

from repro.core import wemd as WE

dists = hnp.arrays(np.float64, st.integers(2, 8),
                   elements=st.floats(0.01, 1.0)).map(
    lambda a: a / a.sum())


def dev_matrix(V, C):
    # rows bounded away from zero so every device has a real distribution
    return hnp.arrays(np.float64, (V, C), elements=st.floats(0.01, 1.0)).map(
        lambda a: a / a.sum(axis=1, keepdims=True))


@given(p=dists)
@settings(max_examples=50, deadline=None)
def test_wemd_zero_iff_equal(p):
    w = np.ones(len(p))
    assert WE.wemd(p, p, w) == 0.0
    q = np.roll(p, 1)
    if not np.allclose(p, q):
        assert WE.wemd(p, q, w) > 0


@given(st.data())
@settings(max_examples=50, deadline=None)
def test_incremental_add_matches_full(data):
    V, C = data.draw(st.integers(2, 8)), data.draw(st.integers(2, 6))
    p_dev = data.draw(dev_matrix(V, C))
    gd = data.draw(hnp.arrays(np.float64, C, elements=st.floats(0.01, 1.0))
                   .map(lambda a: a / a.sum()))
    w = data.draw(hnp.arrays(np.float64, C, elements=st.floats(0.1, 2.0)))
    mask = data.draw(hnp.arrays(np.bool_, V))
    p_sum = p_dev[mask].sum(axis=0)
    size = int(mask.sum())
    cand = WE.wemd_add_candidates(p_sum, size, p_dev, gd, w)
    for v in range(V):
        if mask[v]:
            continue
        m2 = mask.copy()
        m2[v] = True
        assert np.isclose(cand[v], WE.wemd_of_set(p_dev, m2, gd, w)), v


@given(st.data())
@settings(max_examples=50, deadline=None)
def test_swap_matches_full(data):
    V, C = data.draw(st.integers(3, 8)), data.draw(st.integers(2, 5))
    p_dev = data.draw(dev_matrix(V, C))
    gd = np.ones(C) / C
    w = np.ones(C)
    k = data.draw(st.integers(1, V - 1))
    mask = np.zeros(V, bool)
    mask[:k] = True
    p_sum = p_dev[mask].sum(axis=0)
    in_idx = np.flatnonzero(mask)
    out_idx = np.flatnonzero(~mask)
    sw = WE.wemd_swap_candidates(p_sum, k, p_dev, in_idx, out_idx, gd, w)
    for a, i in enumerate(in_idx):
        for b, j in enumerate(out_idx):
            m2 = mask.copy()
            m2[i], m2[j] = False, True
            assert np.isclose(sw[a, b], WE.wemd_of_set(p_dev, m2, gd, w))


@given(st.integers(1, 100), st.integers(1, 256))
@settings(max_examples=50, deadline=None)
def test_sampling_variance_monotone(n, b):
    s = 1.5
    assert WE.sampling_variance(s, n, b) >= WE.sampling_variance(s, n + 1, b)
    assert WE.sampling_variance(s, n, b) >= WE.sampling_variance(s, n, b + 1)


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_group_distribution_is_distribution(data):
    V, C = data.draw(st.integers(1, 8)), data.draw(st.integers(2, 6))
    p_dev = data.draw(dev_matrix(V, C))
    mask = data.draw(hnp.arrays(np.bool_, V))
    g = WE.group_distribution(p_dev, mask)
    if mask.any():
        assert np.isclose(g.sum(), 1.0)
        assert (g >= -1e-12).all()


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_full_group_wemd_to_own_mean_zero(data):
    """Scheduling everyone and defining global = union mean gives WEMD 0."""
    V, C = data.draw(st.integers(1, 6)), data.draw(st.integers(2, 5))
    p_dev = data.draw(dev_matrix(V, C))
    mask = np.ones(V, bool)
    gd = p_dev.mean(axis=0)
    assert WE.wemd_of_set(p_dev, mask, gd, np.ones(C)) < 1e-9
