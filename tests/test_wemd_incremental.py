"""Incremental WEMD algebra: the O(V*C) / O(V^2*C) candidate updates
must agree with recomputing ``wemd_of_set`` from scratch, and the
batched jnp oracles in ``kernels/ref.py`` must agree with the numpy
layer (the same invariant the Pallas kernels are parity-tested
against)."""
import numpy as np
import pytest

from repro.core import wemd as WE

jnp = pytest.importorskip("jax.numpy")
from repro.kernels import ref  # noqa: E402


def make_world(rng, V, C):
    p_dev = rng.dirichlet(np.full(C, 0.4), size=V)
    gd = rng.dirichlet(np.full(C, 3.0))
    cw = rng.uniform(0.5, 1.5, C)
    return p_dev, gd, cw


@pytest.mark.parametrize("V,C", [(6, 4), (12, 10), (20, 3)])
def test_add_candidates_match_scratch(V, C):
    rng = np.random.default_rng(V * C)
    p_dev, gd, cw = make_world(rng, V, C)
    for trial in range(5):
        mask = rng.random(V) < 0.4
        p_sum = p_dev[mask].sum(axis=0)
        size = int(mask.sum())
        cand = WE.wemd_add_candidates(p_sum, size, p_dev, gd, cw)
        for v in range(V):
            if mask[v]:
                continue
            m2 = mask.copy()
            m2[v] = True
            assert np.isclose(cand[v], WE.wemd_of_set(p_dev, m2, gd, cw),
                              atol=1e-12), (trial, v)


@pytest.mark.parametrize("V,C", [(6, 4), (12, 10), (20, 3)])
def test_swap_candidates_match_scratch(V, C):
    rng = np.random.default_rng(V + C)
    p_dev, gd, cw = make_world(rng, V, C)
    for trial in range(5):
        k = int(rng.integers(1, V))
        mask = np.zeros(V, bool)
        mask[rng.choice(V, k, replace=False)] = True
        p_sum = p_dev[mask].sum(axis=0)
        in_idx = np.flatnonzero(mask)
        out_idx = np.flatnonzero(~mask)
        sw = WE.wemd_swap_candidates(p_sum, k, p_dev, in_idx, out_idx,
                                     gd, cw)
        for a, i in enumerate(in_idx):
            for b, j in enumerate(out_idx):
                m2 = mask.copy()
                m2[i], m2[j] = False, True
                assert np.isclose(sw[a, b],
                                  WE.wemd_of_set(p_dev, m2, gd, cw),
                                  atol=1e-12), (trial, i, j)


# ---------------------------------------------------------------------------
# batched jnp oracles (ref.py) vs the numpy layer


@pytest.mark.parametrize("B,V,C", [(1, 8, 5), (3, 16, 10), (2, 33, 7)])
def test_wemd_swap_ref_matches_numpy(B, V, C):
    rng = np.random.default_rng(B * V)
    sizes = np.full(B, 4.0)
    p_dev = rng.dirichlet(np.full(C, 0.4), size=(B, V))
    p_sum = p_dev[:, :4].sum(axis=1)
    gd = p_dev.mean(axis=1)
    cw = rng.uniform(0.5, 1.5, (B, C))
    out = np.asarray(ref.wemd_swap_ref(
        *(jnp.asarray(x) for x in (p_sum, p_dev, gd, cw, sizes))))
    for b in range(B):
        expect = WE.wemd_swap_candidates(p_sum[b], 4, p_dev[b],
                                         np.arange(V), np.arange(V),
                                         gd[b], cw[b])
        np.testing.assert_allclose(out[b], expect, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,V,C", [(1, 8, 5), (3, 16, 10), (2, 33, 7)])
def test_wemd_add_ref_matches_numpy(B, V, C):
    rng = np.random.default_rng(B + V)
    sizes = np.full(B, 3.0)
    p_dev = rng.dirichlet(np.full(C, 0.4), size=(B, V))
    p_sum = p_dev[:, :3].sum(axis=1)
    gd = p_dev.mean(axis=1)
    cw = rng.uniform(0.5, 1.5, (B, C))
    out = np.asarray(ref.wemd_add_ref(
        *(jnp.asarray(x) for x in (p_sum, p_dev, gd, cw, sizes))))
    for b in range(B):
        expect = WE.wemd_add_candidates(p_sum[b], 3, p_dev[b], gd[b], cw[b])
        np.testing.assert_allclose(out[b], expect, atol=1e-5, rtol=1e-5)
