import os
import sys

# Tests run on the single real CPU device (the dry-run subprocesses set
# their own XLA_FLAGS). Keep compilation fast + deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, for the benchmarks.* helpers (mini_fl_world etc.)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
