"""Batched engine (``solve_many``) vs the per-problem numpy solvers,
plus small-V oracle checks of every heuristic against ``exhaustive``."""
import numpy as np
import pytest

from repro.core import scheduling as S

RNG_PROBLEMS = 24  # >= 20 randomized problems per V (acceptance bar)


def random_problem(rng, V, C=8, infeasible_frac=0.0):
    p_dev = rng.dirichlet(np.full(C, 0.4), size=V)
    min_bw = rng.uniform(0.4, 1.6, V)
    if infeasible_frac:
        bad = rng.random(V) < infeasible_frac
        min_bw[bad] = -1.0                       # deadline-infeasible
    return S.Problem(
        p_dev=p_dev, global_dist=rng.dirichlet(np.full(C, 3.0)),
        class_weights=rng.uniform(0.5, 1.5, C),
        sigma=float(rng.uniform(2.0, 6.0)), batch_size=32,
        min_bw=min_bw, total_bw=V * 0.5)


# ---------------------------------------------------------------------------
# small-V oracle: heuristics vs the exact optimum


@pytest.mark.parametrize("V", [6, 9, 12])
def test_heuristics_vs_exhaustive(V):
    rng = np.random.default_rng(V)
    for t in range(8):
        prob = random_problem(rng, V, C=5)
        opt = S.exhaustive(prob).objective
        for fn, bound in ((S.greedy_scheduling, 2.0), (S.fscd, 1.5),
                          (S.coordinate_descent, 2.0)):
            obj = fn(prob).objective
            assert obj >= opt - 1e-9, (fn.__name__, t)
            # loose approximation bound: heuristics stay within a small
            # constant factor of the optimum on these instances
            assert obj <= bound * opt + 1e-9, (fn.__name__, t, obj, opt)


# ---------------------------------------------------------------------------
# batched engine == numpy loop, bitwise masks


@pytest.mark.parametrize("algorithm", ["gs", "fscd"])
@pytest.mark.parametrize("V", [8, 16, 64])
def test_solve_many_matches_numpy(algorithm, V):
    rng = np.random.default_rng(1000 + V)
    probs = [random_problem(rng, V,
                            infeasible_frac=0.2 if t % 3 == 0 else 0.0)
             for t in range(RNG_PROBLEMS)]
    numpy_fn = {"gs": S.greedy_scheduling, "fscd": S.fscd}[algorithm]
    expect = [numpy_fn(p) for p in probs]
    got = S.solve_many(probs, algorithm, backend="jax")
    assert len(got) == len(expect)
    for t, (e, g) in enumerate(zip(expect, got)):
        assert np.array_equal(e.mask, g.mask), (algorithm, V, t)
        assert e.iterations == g.iterations, (algorithm, V, t)
        assert np.isclose(e.objective, g.objective, rtol=0, atol=1e-9)


def test_solve_many_numpy_backend_identity():
    rng = np.random.default_rng(5)
    probs = [random_problem(rng, 12) for _ in range(4)]
    for alg, fn in (("gs", S.greedy_scheduling), ("fscd", S.fscd)):
        got = S.solve_many(probs, alg, backend="numpy")
        for e, g in zip([fn(p) for p in probs], got):
            assert np.array_equal(e.mask, g.mask)


def test_solve_many_mixed_feasibility_and_edge_cases():
    rng = np.random.default_rng(9)
    # one fully infeasible problem in the batch -> empty mask, like numpy
    probs = [random_problem(rng, 10) for _ in range(3)]
    dead = random_problem(rng, 10)
    dead.min_bw[:] = -1.0
    probs.append(dead)
    for alg, fn in (("gs", S.greedy_scheduling), ("fscd", S.fscd)):
        got = S.solve_many(probs, alg)
        for e, g in zip([fn(p) for p in probs], got):
            assert np.array_equal(e.mask, g.mask)
    assert not got[-1].mask.any()


def test_solve_many_validates_inputs():
    rng = np.random.default_rng(2)
    assert S.solve_many([], "gs") == []
    with pytest.raises(ValueError):
        S.solve_many([random_problem(rng, 8)], "not-an-algorithm")
    with pytest.raises(ValueError):
        S.solve_many([random_problem(rng, 8)], "gs", backend="tpu-magic")
    with pytest.raises(ValueError):
        S.solve_many([random_problem(rng, 8), random_problem(rng, 12)], "gs")


def test_trainer_backend_knob_masks_identical():
    """FederatedTrainer(scheduler_backend='jax') schedules the exact
    masks of the numpy path, round for round."""
    import dataclasses

    from benchmarks.common import mini_fl_world
    from repro.fl.rounds import FLConfig, FederatedTrainer

    model, train, test, parts = mini_fl_world(V=10)
    histories = {}
    for backend in ("numpy", "jax"):
        cfg = FLConfig(num_devices=10, available_prob=0.6, batch_size=8,
                       tau=1, scheduler="fedcgd-fscd",
                       scheduler_backend=backend, seed=3, eval_every=0)
        tr = FederatedTrainer(model, train, test, parts, cfg)
        hist = tr.run(3)
        histories[backend] = [(r["num_scheduled"], r["wemd"]) for r in hist]
    assert histories["numpy"] == histories["jax"]
