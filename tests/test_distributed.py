"""Distributed semantics on a small host-device mesh (subprocess: the
device count must be set before jax initializes)."""
import os
import subprocess
import sys

import pytest

# 8 forced host devices rendezvous through one real core: minutes of
# wall-clock per subprocess on CI-sized boxes -> opt-in profile only
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(script: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_federated_step_weighted_aggregation():
    """fed_train_step with schedule weights == manual weighted FedAvg of
    per-silo gradients (paper Eq. 2, tau=1), on a 2x2x2 pod mesh."""
    run_sub('''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh
from repro.launch import shardings as SH
from repro.launch.shapes import InputShape
from repro.fl.distributed import make_train_step, silo_weights
from repro.models import transformer as T
from repro.sharding import ShardingCtx

cfg = get_config("qwen2-7b").reduced()
mesh = make_debug_mesh(2, 2, multi_pod=True)
ctx = ShardingCtx(mesh=mesh, batch_axes=("pod", "data"), model_axis="model",
                  fsdp_axes=("data",), tp=False)
params = T.init(jax.random.key(0), cfg)
B, S = 8, 16
tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
targets = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
mask_silos = np.array([1.0, 0.0])           # only silo 0 scheduled
w = np.repeat(mask_silos / mask_silos.sum() * 2, B // 2)
batch = {"tokens": tokens, "targets": targets,
         "schedule_weights": jnp.asarray(w, jnp.float32)}

step = make_train_step(cfg, ctx, eta=0.1, federated=True)
with mesh:
    new_params, metrics = jax.jit(step)(params, batch)

# manual: gradient on silo-0 half of the batch only
def loss0(p):
    return T.loss_fn(p, cfg, {"tokens": tokens[:4],
                              "targets": targets[:4]})[0]
g0 = jax.grad(loss0)(params)
expect = jax.tree.map(lambda p, g: p - 0.1 * g, params, g0)
err = max(float(jnp.abs(a - b).max())
          for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(expect)))
assert err < 3e-2, err   # bf16-free f32 reduced cfg: tight-ish
print("fed step OK", err)
''')


def test_moe_shard_map_matches_local():
    """Expert-parallel shard_map MoE == single-device MoE."""
    run_sub('''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh
from repro.models import moe as M
from repro.sharding import ShardingCtx

cfg = get_config("qwen3-moe-235b-a22b").reduced()
cfg = dataclasses.replace(cfg, capacity_factor=8.0)
params = M.moe_params_init(jax.random.key(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model))

y_local, aux_local = M.moe_ffn(params, cfg, x, ctx=None)

mesh = make_debug_mesh(4, 2)    # data=4, model=2; E=4 experts /2 shards
ctx = ShardingCtx(mesh=mesh, batch_axes=("data",), model_axis="model",
                  fsdp_axes=(), tp=True)
with mesh:
    y_dist, aux_dist = jax.jit(
        lambda p, xx: M.moe_ffn(p, cfg, xx, ctx=ctx))(params, x)
err = float(jnp.abs(y_dist - y_local).max())
assert err < 1e-4, err
assert abs(float(aux_dist) - float(aux_local)) < 1e-5
print("moe shard_map OK", err)
''')


def test_debug_mesh_dryrun_lowers():
    """A miniature dry-run on an 8-device mesh: every family lowers and
    compiles with the production sharding rules."""
    run_sub('''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_debug_mesh
from repro.launch import shardings as SH
from repro.launch.shapes import InputShape
from repro.fl.distributed import make_train_step

for arch in ["qwen2-7b", "gemma3-27b", "rwkv6-3b", "recurrentgemma-2b",
             "qwen3-moe-235b-a22b"]:
    cfg = get_config(arch).reduced()
    # reduced configs have tiny dims; use a debug shape
    shape = InputShape("debug", 64, 8, "train")
    mesh = make_debug_mesh(4, 2)
    ctx = SH.make_ctx(cfg, mesh, shape)
    ps = SH.param_specs(cfg)
    psh = SH.param_shardings(ps, cfg, ctx)
    bs = SH.input_specs(cfg, shape)
    bsh = SH.batch_shardings(bs, ctx)
    with mesh:
        c = jax.jit(make_train_step(cfg, ctx), in_shardings=(psh, bsh),
                    out_shardings=(psh, None)).lower(ps, bs).compile()
    assert c.cost_analysis().get("flops", 0) > 0
    print(arch, "lowered OK")
''')
