"""repro.obs: span tracing, metrics registry, sinks, and the trainer
instrumentation.

Key contracts:
  * obs disabled (the default) is free: ``from_config`` returns the
    shared ``DISABLED`` singleton, spans are the no-op ``NULL_SPAN``,
    ``instrument_jit`` is the identity, and a fault-free run is
    bitwise-identical (history AND params) to an obs-enabled run on
    both scheduler backends;
  * every round emits one record whose ``phases`` (prep/core/schedule/
    upload/finalize) cover ``round_s`` — the spans wrap the whole body;
  * the host-sync contract (<= 3 fault-free, constant in C) is
    asserted through the ``fl.round.host_syncs`` registry gauge;
  * round records carry ``g_refresh_errors_round`` plus the deprecated
    ``g_refresh_errors`` alias (same per-round value; the trainer
    attribute stays cumulative);
  * the jit-wrapper hook counts compiles on cache growth only — steady
    rounds at a fixed shape add calls but no compiles.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

import repro.core.estimation as E
from repro.configs.paper_cnn import CNNConfig
from repro.data import (sort_and_partition, synthetic_image_dataset,
                        train_test_split)
from repro.faults import FaultConfig
from repro.fl import FederatedTrainer, FLConfig, MultiCellTrainer
from repro.models import build_model
from repro.obs import (COUNT_BUCKETS, DISABLED, NULL_SPAN, Counter,
                       Gauge, Histogram, JSONLSink, MemorySink, Obs,
                       ObsConfig, Registry, Tracer, dumps_record,
                       format_summary, from_config, profile_rounds,
                       read_jsonl)

LOSSY = FaultConfig(outage_prob=0.3, dropout_prob=0.2,
                    corrupt_prob=0.3, reshadow_std_db=4.0,
                    clip_delta_norm=10.0, backfill=True)

PHASES = ("prep", "core", "schedule", "upload", "finalize")

RECORD_KEYS = ("round", "kind", "phases", "round_s", "host_syncs",
               "upload_bytes", "sched_iterations", "num_uploaded",
               "num_failed", "failure_causes", "num_sanitized",
               "num_clipped", "num_backfilled",
               "g_refresh_errors_round", "g_refresh_errors")


@pytest.fixture(scope="module")
def micro_world():
    ds = synthetic_image_dataset(num_classes=2, num_per_class=40,
                                 image_size=8, seed=0)
    train, test = train_test_split(ds, seed=0)
    parts = sort_and_partition(train.labels, 8, 1,
                               np.random.default_rng(0))
    model = build_model(CNNConfig(name="micro-cnn", kind="paper_cnn",
                                  num_classes=2, image_size=8,
                                  dropout=False, width=0.25))
    return model, train, test, parts


def micro_cfg(backend="jax", avail=1.0, cells=1, **kw):
    kw.setdefault("scheduler", "fedcgd-fscd")
    return FLConfig(num_devices=8, available_prob=avail, batch_size=2,
                    tau=1, scheduler_backend=backend, eval_every=0,
                    seed=0, num_cells=cells, **kw)


def make_trainer(micro_world, **cfg_kw):
    model, train, test, parts = micro_world
    return FederatedTrainer(model, train, test, parts,
                            micro_cfg(**cfg_kw))


def params_equal(a, b):
    return all((np.asarray(x) == np.asarray(y)).all()
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# metrics unit level


def test_counter_monotone():
    c = Counter("x")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_last_write_wins():
    g = Gauge("x")
    assert math.isnan(g.value)
    g.set(3)
    g.set(7)
    assert g.value == 7.0


def test_histogram_percentiles():
    h = Histogram("t", buckets=(1, 2, 5, 10, 100))
    for v in (1.5, 1.5, 1.5, 1.5, 1.5, 1.5, 1.5, 1.5, 1.5, 80):
        h.observe(v)
    assert h.count == 10
    # p50 rank lands in the (1, 2] bucket -> upper edge 2
    assert h.percentile(0.5) == 2
    # p99 rank is the outlier's bucket; clamped to the observed max
    assert h.percentile(0.99) == 80
    assert h.percentile(0.0) == 1.5
    assert h.percentile(1.0) == 80
    assert h.mean == pytest.approx((9 * 1.5 + 80) / 10)
    with pytest.raises(ValueError):
        h.percentile(50)
    assert math.isnan(Histogram("empty").percentile(0.5))


def test_registry_reset_preserves_identity():
    r = Registry()
    c = r.counter("a")
    g = r.gauge("b")
    h = r.histogram("c", COUNT_BUCKETS)
    c.inc(5)
    g.set(2)
    h.observe(3)
    r.reset()
    assert r.counter("a") is c and c.value == 0
    assert r.gauge("b") is g and math.isnan(g.value)
    assert r.histogram("c") is h and h.count == 0


def test_registry_snapshot_json():
    import json
    r = Registry()
    r.counter("a").inc()
    r.histogram("h").observe(0.5)
    snap = r.snapshot()
    assert snap["counters"]["a"] == 1
    assert snap["histograms"]["h"]["count"] == 1
    json.dumps(snap)        # plain data, serializable


# ---------------------------------------------------------------------------
# tracer unit level


def test_tracer_nesting_and_drain():
    reg = Registry()
    tr = Tracer(reg)
    with tr.span("outer"):
        with tr.span("inner"):
            pass
        with tr.span("inner"):
            pass
    recs = tr.records
    # children complete (and are recorded) before their parent
    assert [(r.name, r.depth) for r in recs] == [
        ("inner", 1), ("inner", 1), ("outer", 0)]
    assert reg.histogram("span.inner").count == 2
    assert reg.histogram("span.outer").count == 1
    drained = tr.drain()
    assert len(drained) == 3 and tr.records == []


def test_trace_decorator_respects_enabled_flag():
    reg = Registry()
    tr = Tracer(reg, enabled=False)

    @tr.trace("f")
    def f():
        return 42

    assert f() == 42
    assert reg.histogram("span.f").count == 0
    tr.enabled = True
    assert f() == 42
    assert reg.histogram("span.f").count == 1


def test_disabled_facade_is_shared_and_null():
    assert from_config(ObsConfig()) is DISABLED
    assert from_config(None) is DISABLED
    assert DISABLED.span("anything") is NULL_SPAN

    def fn():
        return 1
    assert DISABLED.instrument_jit("fn", fn) is fn


def test_obs_config_validation():
    with pytest.raises(ValueError):
        ObsConfig(ring_size=-1)
    with pytest.raises(ValueError):
        ObsConfig(jsonl_path="")


# ---------------------------------------------------------------------------
# sinks


def test_memory_sink_ring():
    s = MemorySink(capacity=3)
    for i in range(5):
        s.emit({"i": i})
    assert [r["i"] for r in s.records()] == [2, 3, 4]
    with pytest.raises(ValueError):
        MemorySink(capacity=0)


def test_jsonl_roundtrip_numpy_types(tmp_path):
    path = str(tmp_path / "m.jsonl")
    sink = JSONLSink(path)
    rec = {"i": np.int64(3), "f": np.float32(0.5), "b": np.bool_(True),
           "a": np.arange(3), "s": "x"}
    sink.emit(rec)
    sink.close()
    (back,) = read_jsonl(path)
    assert back == {"i": 3, "f": 0.5, "b": True, "a": [0, 1, 2], "s": "x"}
    assert dumps_record(rec) == dumps_record(rec)


def test_round_record_attaches_phase_breakdown():
    obs = Obs(enabled=True, sinks=[MemorySink(8)])
    with obs.span("round"):
        with obs.span("prep"):
            pass
        with obs.span("core"):
            pass
    out = obs.round_record({"round": 0})
    assert out["kind"] == "round"
    assert set(out["phases"]) == {"prep", "core"}
    assert out["round_s"] >= sum(out["phases"].values())
    assert obs.records() == [out]


# ---------------------------------------------------------------------------
# trainer integration


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_enabled_vs_disabled_bitwise(micro_world, backend):
    """Acceptance: observability off/on never changes the training
    trajectory — histories equal, params bitwise, same host syncs."""
    t0 = make_trainer(micro_world, backend=backend)
    h0 = t0.run(3)
    t1 = make_trainer(micro_world, backend=backend,
                      obs=ObsConfig(enabled=True))
    h1 = t1.run(3)
    assert h0 == h1
    assert params_equal(t0.params, t1.params)
    assert t0.last_round_host_syncs == t1.last_round_host_syncs


@pytest.mark.parametrize("backend", ["numpy", "jax"])
@pytest.mark.parametrize("faults", [FaultConfig(), LOSSY],
                         ids=["fault_free", "lossy"])
def test_round_record_schema(micro_world, backend, faults):
    tr = make_trainer(micro_world, backend=backend, faults=faults,
                      obs=ObsConfig(enabled=True))
    tr.run(2)
    recs = tr.obs.records()
    assert len(recs) == 2
    for rec in recs:
        for key in RECORD_KEYS:
            assert key in rec, key
        assert set(rec["phases"]) == set(PHASES)
        assert rec["g_refresh_errors"] == rec["g_refresh_errors_round"]
        # trainer history records never grow obs-only keys
        assert "phases" not in tr.history[rec["round"]]


def test_phases_cover_round_time(micro_world):
    """Acceptance: per-round phase timings sum to within 10% of the
    round wall-clock (everything run_round does is inside a phase)."""
    tr = make_trainer(micro_world, obs=ObsConfig(enabled=True))
    tr.run(3)
    for rec in tr.obs.records():
        assert sum(rec["phases"].values()) >= 0.9 * rec["round_s"]


def test_host_sync_contract_through_registry(micro_world):
    """Acceptance: the <=3-and-constant-in-C host-sync contract is
    asserted through the metrics registry, not an ad-hoc attribute."""
    model, train, test, parts = micro_world
    syncs = {}
    for C in (2, 4):
        mc = MultiCellTrainer(model, train, test, parts,
                              micro_cfg(cells=C,
                                        obs=ObsConfig(enabled=True)))
        for j in range(2):
            mc.run_round(j)
        g = mc.obs.metrics.gauge("fl.round.host_syncs")
        assert g.value <= 3
        assert g.value == mc.last_round_host_syncs
        syncs[C] = g.value
    assert syncs[2] == syncs[4]


def test_multicell_cells_stay_bitwise_with_obs(micro_world):
    """Engine-level observability must not disturb the cells: an
    obs-enabled C=2 run matches the obs-disabled one bitwise."""
    model, train, test, parts = micro_world
    mc0 = MultiCellTrainer(model, train, test, parts, micro_cfg(cells=2))
    mc1 = MultiCellTrainer(model, train, test, parts,
                           micro_cfg(cells=2,
                                     obs=ObsConfig(enabled=True)))
    h0 = [mc0.run_round(j) for j in range(2)]
    h1 = [mc1.run_round(j) for j in range(2)]
    assert h0 == h1
    assert params_equal(jax.device_get(mc0._params_c),
                        jax.device_get(mc1._params_c))
    kinds = [r["kind"] for r in mc1.obs.records()]
    assert kinds.count("multicell_round") == 2
    assert kinds.count("round") == 4          # one per cell per round


def test_g_refresh_errors_round_and_alias(micro_world, monkeypatch):
    """Satellite: the per-round key is ``g_refresh_errors_round`` (the
    deprecated alias carries the same value), the attribute stays
    cumulative, and the registry total matches."""
    def boom(*a, **k):
        raise ValueError("synthetic Eq. 12 failure")
    monkeypatch.setattr(E, "g_hat", boom)
    tr = make_trainer(micro_world, obs=ObsConfig(enabled=True))
    hist = tr.run(2)
    assert all(h["g_refresh_errors_round"] == 1 for h in hist)
    assert all(h["g_refresh_errors"] == 1 for h in hist)
    assert tr.g_refresh_errors == 2
    assert tr.obs.metrics.counter(
        "fl.g_refresh_errors_total").value == 2


def test_compile_metrics_steady_state(micro_world):
    """The jit hook counts compiles only on cache growth: round 0 pays
    them, later rounds at the same shape add calls, not compiles."""
    tr = make_trainer(micro_world, obs=ObsConfig(enabled=True))
    tr.run_round(0)
    m = tr.obs.metrics
    compiles0 = m.counter("xla.compiles_total").value
    seconds0 = m.counter("xla.compile_seconds_total").value
    assert compiles0 >= 2            # round core + finalize core
    assert seconds0 > 0
    for j in range(1, 3):
        tr.run_round(j)
    assert m.counter("xla.compiles_total").value == compiles0
    assert m.counter("xla.compile_seconds_total").value == seconds0
    assert m.counter("xla.calls.round_core").value == 3


def test_solve_many_scheduler_metrics(micro_world):
    tr = make_trainer(micro_world, backend="jax",
                      obs=ObsConfig(enabled=True))
    tr.run(2)
    m = tr.obs.metrics
    assert m.counter("sched.solve_many_calls.jax").value == 2
    assert m.counter("sched.problems_total").value == 2
    assert m.counter("sched.iterations_total").value >= 2
    assert m.histogram("span.solve_many.jax").count == 2


def test_fault_and_failure_metrics(micro_world):
    tr = make_trainer(micro_world, faults=LOSSY,
                      obs=ObsConfig(enabled=True))
    hist = tr.run(3)
    m = tr.obs.metrics
    assert m.counter("faults.rounds_drawn").value == 3
    injected = sum(c.value for name, c in m.counters.items()
                   if name.startswith("faults.injected."))
    assert injected > 0
    causes = {}
    for h in hist:
        for c, n in h["failure_causes"].items():
            causes[c] = causes.get(c, 0) + n
    for cause, n in causes.items():
        assert m.counter(f"fl.failures.{cause}").value == n
    assert m.counter("fl.uploads_total").value == \
        sum(h["num_uploaded"] for h in hist)


def test_jsonl_end_to_end(micro_world, tmp_path):
    """Acceptance: a lossy run with a JSONL sink produces valid JSONL
    with per-round phase timings."""
    path = str(tmp_path / "metrics.jsonl")
    tr = make_trainer(micro_world, faults=LOSSY,
                      obs=ObsConfig(enabled=True, jsonl_path=path))
    tr.run(3)
    tr.obs.close()
    rows = read_jsonl(path)
    assert len(rows) == 3
    for row in rows:
        assert row["kind"] == "round"
        assert set(row["phases"]) == set(PHASES)
        assert sum(row["phases"].values()) >= 0.9 * row["round_s"]
    summary = format_summary(tr.obs.metrics)
    assert "span timings" in summary and "fl.rounds_total" in summary


def test_profile_rounds_smoke(micro_world, tmp_path):
    tr = make_trainer(micro_world)
    try:
        out = profile_rounds(tr, 1, tmp_path / "trace", warmup=1)
    except Exception as exc:        # pragma: no cover - env dependent
        pytest.skip(f"jax.profiler unavailable: {exc}")
    assert len(tr.history) == 2     # warmup + traced round both ran
    import os
    assert os.path.isdir(out)
    with pytest.raises(ValueError):
        profile_rounds(tr, 0, tmp_path / "t2")
