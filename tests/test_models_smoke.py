"""Per-architecture smoke tests (brief deliverable f): every assigned
architecture instantiates a REDUCED variant of the same family (<=2
pattern periods, d_model<=256, <=4 experts) and runs one forward + one
train step on CPU asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.models import build_model

ALL_ARCHS = sorted(ARCHITECTURES)


def make_batch(cfg, B=2, S=24, key=0):
    k = jax.random.key(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
             "targets": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frame_embeddings"] = jax.random.normal(
            k, (B, S, cfg.encoder_dim))
        del batch["tokens"]
    if cfg.family == "vlm":
        batch["encoder_embeddings"] = jax.random.normal(
            k, (B, cfg.num_encoder_tokens, cfg.encoder_dim))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    cfg.validate()
    # the exact numbers of the brief
    briefs = {
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    }
    L, d, H, KV, dff, V = briefs[arch]
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == H and cfg.num_kv_heads == KV
    assert cfg.d_ff == dff and cfg.vocab_size == V


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= max(2, cfg.pattern_period)
    assert cfg.d_model <= 256
    assert cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)

    logits, aux, _ = model.forward(params, batch)
    B = 2
    S = 24
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one SGD train step
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    new_params = jax.tree.map(lambda p, g: p - 0.01 * g, params, grads)
    loss2, _ = model.loss_fn(new_params, batch)
    assert np.isfinite(float(loss2))
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree.leaves(grads)))
    assert float(gn) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_decode_consistency(arch):
    """prefill + one serve_step == full forward at the next position."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S + 1, key=1)

    full, _, _ = model.forward(params, batch)
    seq_keys = ("tokens", "frame_embeddings")
    pre = {k: (v[:, :S] if k in seq_keys else v) for k, v in batch.items()}
    _, _, cache = model.forward(params, pre, collect_cache=True,
                                cache_len=S + 8)
    step = {k: (v[:, S:S + 1] if k in seq_keys else v)
            for k, v in batch.items()}
    step.pop("targets", None)
    dec, _ = model.serve_step(params, cache, step)
    err = np.abs(np.asarray(dec[:, 0]) - np.asarray(full[:, S])).max()
    scale = max(np.abs(np.asarray(full[:, S])).max(), 1.0)
    assert err < 2e-3 * scale, (arch, err, scale)


def test_paper_cnn_and_resnet():
    from repro.configs import CNN_MODELS
    for name, cfg in CNN_MODELS.items():
        cfg = cfg.reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        imgs = jax.random.normal(jax.random.key(1),
                                 (4, cfg.image_size, cfg.image_size, 3))
        batch = {"images": imgs,
                 "labels": jnp.zeros((4,), jnp.int32)}
        loss, metrics = model.loss_fn(params, batch)
        assert np.isfinite(float(loss))
        logits = model.forward(params, batch)
        assert logits.shape == (4, cfg.num_classes)
