"""Fault injection + resilient round loop (repro.faults).

Covers: the inert-by-default guarantee (zero fault probability ==
bitwise the fault-free trainer), fault determinism across runs and
scheduler backends, NaN/Inf sanitization, norm clipping, one-shot
backfill, zero-upload degradation, the all-False aggregate guard, the
Eq. 12 narrow-exception counter, and the B* = -1 infeasibility
invariant across every scheduling policy.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.estimation as E
from repro.configs.paper_cnn import PAPER_CNN_CIFAR10
from repro.core import scheduling as S
from repro.core.bandwidth import deadline_met, min_bandwidth
from repro.data import (sort_and_partition, synthetic_image_dataset,
                        train_test_split)
from repro.faults import (FaultConfig, FaultInjector, RoundFaults,
                          sanitize_updates)
from repro.fl import FederatedTrainer, FLConfig, aggregate
from repro.models import build_model
from repro.wireless.channel import apply_shadow_db


@pytest.fixture(scope="module")
def small_world():
    ds = synthetic_image_dataset(num_classes=4, num_per_class=60,
                                 image_size=16, noise=0.4, seed=0)
    train, test = train_test_split(ds, seed=0)
    cfg = dataclasses.replace(PAPER_CNN_CIFAR10.reduced(), num_classes=4)
    return build_model(cfg), train, test


def make_trainer(small_world, faults, backend="numpy", seed=0, V=8,
                 **fl_kwargs):
    model, train, test = small_world
    rng = np.random.default_rng(seed)
    parts = sort_and_partition(train.labels, V, 2, rng)
    fl = FLConfig(num_devices=V, available_prob=0.8, batch_size=8, tau=1,
                  scheduler="fedcgd-fscd", scheduler_backend=backend,
                  eval_every=0, seed=seed, faults=faults, **fl_kwargs)
    return FederatedTrainer(model, train, test, parts, fl)


def params_finite(params) -> bool:
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(params))


LOSSY = FaultConfig(outage_prob=0.3, dropout_prob=0.2,
                    deadline_miss_prob=0.1, corrupt_prob=0.4,
                    reshadow_std_db=6.0, clip_delta_norm=5.0)

TELEMETRY_FIELDS = ("num_uploaded", "num_failed", "failure_causes",
                    "num_backfilled", "num_sanitized", "num_clipped",
                    "num_infeasible", "g_refresh_errors")


# ---------------------------------------------------------------------------
# unit level


def test_fault_config_validation():
    with pytest.raises(ValueError):
        FaultConfig(outage_prob=1.5)
    with pytest.raises(ValueError):
        FaultConfig(corrupt_modes=("garbage",))
    with pytest.raises(ValueError):
        FaultConfig(reshadow_std_db=-1.0)
    assert not FaultConfig().injection_enabled
    assert FaultConfig(outage_prob=0.1).injection_enabled


def test_injector_inert_and_deterministic():
    inj = FaultInjector(FaultConfig(), num_devices=16, base_seed=0)
    rf = inj.draw(3)
    assert not rf.dropout.any() and not rf.corrupt.any()
    lossy = FaultInjector(LOSSY, num_devices=16, base_seed=0)
    a, b = lossy.draw(7), lossy.draw(7)
    for f in dataclasses.fields(RoundFaults):
        np.testing.assert_array_equal(getattr(a, f.name), getattr(b, f.name))
    # different rounds give different realisations
    c = lossy.draw(8)
    assert any((getattr(a, f.name) != getattr(c, f.name)).any()
               for f in dataclasses.fields(RoundFaults))


def test_apply_shadow_db_and_deadline_met():
    gains = np.array([1e-9, 1e-9])
    deeper = apply_shadow_db(gains, np.array([10.0, -10.0]))
    np.testing.assert_allclose(deeper, [1e-10, 1e-8])
    # a device allocated exactly B* meets the deadline at the measured
    # gain and misses it once the gain fades
    sh, noise, bits, d = 1e-9, 1e-17, 1e5, 2.0
    b = min_bandwidth(bits, d, np.array([sh]), noise)
    assert b[0] > 0
    assert deadline_met(b, bits, d, np.array([sh]), noise)[0]
    assert not deadline_met(b, bits, d, np.array([sh * 0.5]), noise)[0]
    # infeasible marker is never met
    assert not deadline_met(np.array([-1.0]), bits, d, np.array([sh]),
                            noise)[0]


def test_sanitize_nan_guard_and_clip():
    deltas = {"w": jnp.stack([jnp.ones((3,)), jnp.ones((3,)) * 10.0,
                              jnp.full((3,), jnp.nan)])}
    norms = np.array([np.sqrt(3.0), np.sqrt(300.0), np.nan])
    res = sanitize_updates(deltas, [0, 1, 2], {}, clip_norm=2.0, norms=norms)
    assert res.kept == [0, 1]
    assert res.dropped_nonfinite == [2]
    assert res.clipped == [1]
    clipped = res.deltas[1]["w"]
    np.testing.assert_allclose(float(jnp.linalg.norm(clipped)), 2.0,
                               rtol=1e-5)
    # overrides shadow the stacked row
    res2 = sanitize_updates(deltas, [0], {0: {"w": jnp.full((3,), jnp.inf)}},
                            clip_norm=0.0)
    assert res2.kept == [] and res2.dropped_nonfinite == [0]


def test_aggregate_raises_on_empty_mask():
    """Regression: an all-False mask used to silently zero the model."""
    stacked = {"w": jnp.ones((3, 4))}
    with pytest.raises(ValueError, match="all-False"):
        aggregate(stacked, np.zeros(3, bool))


# ---------------------------------------------------------------------------
# infeasibility invariant (B* = -1 can never be scheduled)


def _infeasible_problem(rng):
    V, C = 10, 5
    min_bw = rng.uniform(0.5, 1.5, V)
    min_bw[[0, 3, 7]] = -1.0
    return S.Problem(
        p_dev=rng.dirichlet(np.ones(C) * 0.4, size=V),
        global_dist=np.ones(C) / C, class_weights=np.ones(C),
        sigma=1.0, batch_size=32, min_bw=min_bw, total_bw=6.0)


def test_infeasible_never_scheduled_any_policy():
    rng = np.random.default_rng(0)
    prob = _infeasible_problem(rng)
    bad = prob.min_bw < 0
    solvers = {
        "gs": lambda: S.greedy_scheduling(prob),
        "fscd": lambda: S.fscd(prob),
        "cd": lambda: S.coordinate_descent(prob, np.random.default_rng(1)),
        "exhaustive": lambda: S.exhaustive(prob),
        "bc": lambda: S.best_channel(prob, rng.random(10)),
        "bn": lambda: S.best_norm(prob, rng.random(10)),
        "poc": lambda: S.power_of_choice(prob, rng.random(10), 6,
                                         np.random.default_rng(2)),
        "fcbs": lambda: S.fed_cbs(prob, np.ones(10), 3),
        "random": lambda: S.random_schedule(prob,
                                            np.random.default_rng(3)),
    }
    for name, fn in solvers.items():
        sched = fn()
        assert not (sched.mask & bad).any(), name
    for algo in ("gs", "fscd"):
        for backend in ("numpy", "jax"):
            sched = S.solve_many([prob], algo, backend=backend)[0]
            assert not (sched.mask & bad).any(), (algo, backend)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_infeasible_never_scheduled_end_to_end(small_world, backend):
    """Through min_bandwidth: a brutal deadline marks most devices
    B* = -1 and no policy may ever schedule one of them."""
    tr = make_trainer(small_world, FaultConfig(), backend=backend,
                      deadline_s=1e-4)
    seen = []

    orig = tr._schedule

    def spy(prob, avail_idx, gains, delta_norms, round_idx):
        sched = orig(prob, avail_idx, gains, delta_norms, round_idx)
        seen.append((prob.min_bw.copy(), sched.mask.copy()))
        return sched

    tr._schedule = spy
    hist = tr.run(3)
    assert any(h["num_infeasible"] > 0 for h in hist)
    assert seen
    for min_bw, mask in seen:
        assert not (mask & (min_bw < 0)).any()


# ---------------------------------------------------------------------------
# trainer level: inertness, determinism, resilience


def test_zero_fault_config_is_bitwise_inert(small_world):
    """Outage probability 0 => the resilient loop IS the old loop: two
    differently-seeded (but all-zero) fault configs cannot diverge."""
    t1 = make_trainer(small_world, FaultConfig())
    t2 = make_trainer(small_world, FaultConfig(seed=1234, backfill=False,
                                               estimate_decay=0.9))
    h1, h2 = t1.run(3), t2.run(3)
    assert h1 == h2
    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t2.params)):
        assert (np.asarray(a) == np.asarray(b)).all()
    for h in h1:
        assert h["num_failed"] == 0 and h["num_sanitized"] == 0


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_fault_determinism_across_runs(small_world, backend):
    """Same seed + same fault knobs => bitwise-identical history."""
    t1 = make_trainer(small_world, LOSSY, backend=backend)
    t2 = make_trainer(small_world, LOSSY, backend=backend)
    assert t1.run(4) == t2.run(4)
    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t2.params)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_lossy_run_survives_and_reports(small_world):
    """Multi-round run with injected NaN/Inf deltas, outages and
    dropouts completes without exceptions or non-finite params, and the
    records carry the failure telemetry."""
    tr = make_trainer(small_world, LOSSY)
    hist = tr.run(6)
    assert params_finite(tr.params)
    for h in hist:
        for field in TELEMETRY_FIELDS:
            assert field in h, field
        assert set(h["failure_causes"]) == {"dropout", "deadline", "outage",
                                            "corrupt"}
        assert np.isfinite(h["g_hat"]) and np.isfinite(h["sigma_hat"])
    assert sum(h["num_failed"] for h in hist) > 0
    assert sum(sum(h["failure_causes"].values()) for h in hist) > 0


def test_backfill_reschedules_failed_slots(small_world):
    """With heavy outages but clean backfill candidates, the one-shot
    reschedule recovers uploads in the residual bandwidth."""
    fc = FaultConfig(outage_prob=0.6, backfill=True)
    tr = make_trainer(small_world, fc, V=12)
    hist = tr.run(6)
    assert sum(h["num_backfilled"] for h in hist) > 0
    # backfilled uploads count toward the landed total
    for h in hist:
        assert h["num_uploaded"] <= h["num_scheduled"] + h["num_backfilled"]
    # and disabling backfill recovers nothing
    tr2 = make_trainer(small_world, dataclasses.replace(fc, backfill=False),
                       V=12)
    assert all(h["num_backfilled"] == 0 for h in tr2.run(3))


def test_zero_upload_round_degrades_gracefully(small_world):
    """dropout_prob = 1: nothing ever lands — params freeze, estimates
    decay toward their priors, and no round raises."""
    tr = make_trainer(small_world, FaultConfig(dropout_prob=1.0,
                                               estimate_decay=0.5))
    p0 = jax.tree.map(lambda x: np.asarray(x).copy(), tr.params)
    hist = tr.run(3)
    for h in hist:
        assert h["num_uploaded"] == 0
        assert h["num_failed"] == h["num_scheduled"]
        assert h["failure_causes"]["dropout"] == h["num_scheduled"]
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(tr.params)):
        assert (np.asarray(a) == np.asarray(b)).all()
    # g_hat pulled toward its prior g_init
    assert hist[-1]["g_hat"] == pytest.approx(tr.cfg.g_init)


def test_nan_corruption_never_reaches_params(small_world):
    """Every corrupted payload is NaN; the guard must drop them all."""
    fc = FaultConfig(corrupt_prob=0.8, corrupt_modes=("nan", "inf"))
    tr = make_trainer(small_world, fc)
    hist = tr.run(4)
    assert params_finite(tr.params)
    assert sum(h["num_sanitized"] for h in hist) > 0
    assert all(h["num_clipped"] == 0 for h in hist)


def test_explode_corruption_is_clipped(small_world):
    fc = FaultConfig(corrupt_prob=1.0, corrupt_modes=("explode",),
                     corrupt_scale=1e6, clip_delta_norm=1.0)
    tr = make_trainer(small_world, fc)
    hist = tr.run(2)
    assert params_finite(tr.params)
    assert sum(h["num_clipped"] for h in hist) > 0
    # with clipping on, exploded uploads still land
    assert sum(h["num_uploaded"] for h in hist) > 0


def test_g_refresh_error_counter(small_world, monkeypatch):
    """Satellite: the Eq. 12 refresh guard is narrow and counted."""
    def boom(*a, **k):
        raise ValueError("synthetic Eq. 12 failure")
    monkeypatch.setattr(E, "g_hat", boom)
    tr = make_trainer(small_world, FaultConfig())
    hist = tr.run(2)
    assert all(h["g_refresh_errors"] == 1 for h in hist)
    assert tr.g_refresh_errors == 2
    # and an unexpected exception type is NOT swallowed
    def boom2(*a, **k):
        raise RuntimeError("must propagate")
    monkeypatch.setattr(E, "g_hat", boom2)
    with pytest.raises(RuntimeError):
        tr.run_round(2)
