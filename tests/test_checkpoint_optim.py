"""Checkpointing + optimizers."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.optim import adamw, make_optimizer, sgd
from repro.optim.optimizers import apply_updates


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_pytree(path, tree, metadata={"step": 3})
        back = load_pytree(path, tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(x, y)
        assert os.path.exists(path + ".meta.json")


def test_checkpoint_model_params():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("qwen2-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m.npz")
        save_pytree(path, params)
        back = load_pytree(path, params)
        batch = {"tokens": jnp.zeros((1, 8), jnp.int32),
                 "targets": jnp.zeros((1, 8), jnp.int32)}
        l1, _ = model.loss_fn(params, batch)
        l2, _ = model.loss_fn(back, batch)
        assert abs(float(l1) - float(l2)) < 1e-6


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2)


def test_sgd_converges():
    opt = sgd(0.1)
    p = {"w": jnp.zeros(4)}
    state = opt.init(p)
    for _ in range(100):
        g = jax.grad(quad_loss)(p)
        upd, state = opt.update(g, state, p)
        p = apply_updates(p, upd)
    assert float(quad_loss(p)) < 1e-6


def test_sgd_momentum_converges():
    opt = sgd(0.05, momentum=0.9)
    p = {"w": jnp.zeros(4)}
    state = opt.init(p)
    for _ in range(200):
        g = jax.grad(quad_loss)(p)
        upd, state = opt.update(g, state, p)
        p = apply_updates(p, upd)
    assert float(quad_loss(p)) < 1e-4


def test_adamw_converges():
    opt = adamw(0.1)
    p = {"w": jnp.zeros(4)}
    state = opt.init(p)
    for _ in range(300):
        g = jax.grad(quad_loss)(p)
        upd, state = opt.update(g, state, p)
        p = apply_updates(p, upd)
    assert float(quad_loss(p)) < 1e-4


def test_make_optimizer():
    assert make_optimizer("sgd", 0.1)
    assert make_optimizer("adamw", 0.001)
