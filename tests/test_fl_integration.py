"""End-to-end FL behaviour: Algorithm 3 on synthetic data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_cnn import PAPER_CNN_CIFAR10
from repro.data import (dirichlet_partition, sort_and_partition,
                        synthetic_image_dataset, train_test_split)
from repro.fl import FederatedTrainer, FLConfig, aggregate
from repro.fl.client import make_local_update
from repro.models import build_model


@pytest.fixture(scope="module")
def small_world():
    ds = synthetic_image_dataset(num_classes=4, num_per_class=80,
                                 image_size=16, noise=0.4, seed=0)
    train, test = train_test_split(ds, seed=0)
    cfg = PAPER_CNN_CIFAR10.reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, num_classes=4)
    model = build_model(cfg)
    return model, train, test


def make_trainer(small_world, scheduler, V=8, rounds_seed=0, tau=1):
    model, train, test = small_world
    rng = np.random.default_rng(rounds_seed)
    parts = sort_and_partition(train.labels, V, 2, rng)
    fl = FLConfig(num_devices=V, available_prob=0.8, batch_size=8, tau=tau,
                  scheduler=scheduler, eval_every=0, seed=rounds_seed)
    return FederatedTrainer(model, train, test, parts, fl)


@pytest.mark.parametrize("scheduler", ["fedcgd-fscd", "fedcgd-gs", "bc",
                                       "bn", "poc", "fcbs", "random"])
def test_every_scheduler_runs_a_round(small_world, scheduler):
    tr = make_trainer(small_world, scheduler)
    rec = tr.run_round(0)
    assert rec["num_scheduled"] >= 0
    assert np.isfinite(rec["mean_local_loss"])
    assert rec["num_scheduled"] <= rec["num_available"]


@pytest.mark.slow
def test_fl_learns(small_world):
    model, train, test = small_world
    rng = np.random.default_rng(3)
    parts = sort_and_partition(train.labels, 8, 2, rng)
    fl = FLConfig(num_devices=8, available_prob=0.8, batch_size=8, tau=1,
                  eta=0.05, scheduler="fedcgd-fscd", eval_every=0, seed=3)
    tr = FederatedTrainer(model, train, test, parts, fl)
    tr.run(16)
    # 4 classes, chance = 0.25: the aggregated model must beat chance
    accs = [tr.evaluate()]
    tr.run(4)
    accs.append(tr.evaluate())
    assert np.isfinite(accs[-1])
    assert max(accs) > 0.30, accs


def test_aggregation_weighted_mean():
    params = {"w": jnp.arange(12.0).reshape(3, 4)}
    stacked = {"w": jnp.stack([params["w"], params["w"] + 1,
                               params["w"] + 10])}
    mask = np.array([True, False, True])
    out = aggregate(stacked, mask)
    np.testing.assert_allclose(out["w"], params["w"] + 5.0)


def test_local_update_equals_manual_sgd():
    """tau=2 vmapped local update == hand-rolled SGD per device."""
    model, train, _ = (None, None, None)
    key = jax.random.key(0)
    W0 = {"w": jax.random.normal(key, (5, 3))}

    def loss_fn(p, batch, rng=None):
        x, y = batch["x"], batch["y"]
        pred = x @ p["w"]
        l = jnp.mean((pred - y) ** 2)
        return l, {}

    upd = make_local_update(loss_fn, eta=0.1, tau=2)
    V, b = 3, 4
    xs = jax.random.normal(jax.random.key(1), (V, 2, b, 5))
    ys = jax.random.normal(jax.random.key(2), (V, 2, b, 3))
    batches = {"x": xs, "y": ys}
    new, losses = upd(W0, batches, jax.random.key(3))
    assert losses.shape == (V,)
    for v in range(V):
        p = dict(W0)
        for t in range(2):
            batch = {"x": xs[v, t], "y": ys[v, t]}
            g = jax.grad(lambda pp: loss_fn(pp, batch)[0])(p)
            p = {"w": p["w"] - 0.1 * g["w"]}
        np.testing.assert_allclose(new["w"][v], p["w"], atol=1e-5)


def test_sigma_and_g_estimates_positive(small_world):
    tr = make_trainer(small_world, "fedcgd-fscd")
    tr.run(3)
    assert tr.sigma_hat > 0
    assert tr.g_hat > 0


def test_fedcgd_reduces_wemd_vs_random(small_world):
    """Tab. II analogue: FedCGD's scheduled sets have lower WEMD than
    random best-effort scheduling on heterogeneous devices."""
    tr_f = make_trainer(small_world, "fedcgd-fscd", rounds_seed=1)
    tr_r = make_trainer(small_world, "random", rounds_seed=1)
    h_f = tr_f.run(8)
    h_r = tr_r.run(8)
    # compare pure label-distribution EMD (unit weights) of chosen groups
    import repro.core.wemd as WE
    def mean_emd(tr, hist):
        # recompute with unit weights for comparability
        return np.mean([h["wemd"] / max(h["g_hat"], 1e-9) for h in hist])
    assert np.mean([h["wemd"] for h in h_f]) <= \
        np.mean([h["wemd"] for h in h_r]) * 1.5


def test_virtual_model_fc_difference():
    from repro.core.cgd import fc_difference
    from repro.fl.virtual import virtual_step

    def loss_fn(p, batch, rng=None):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2), {}

    p = {"w": jnp.ones((4, 2))}
    batch = {"x": jnp.ones((8, 4)), "y": jnp.zeros((8, 2))}
    v, grads, loss = virtual_step(loss_fn, p, batch, eta=0.1, tau=1)
    assert float(fc_difference(p, v)) > 0
    # gradient step actually taken
    np.testing.assert_allclose(
        v["w"], p["w"] - 0.1 * grads["w"], atol=1e-6)
