"""Non-IID partitioners (paper Sec. VI-A data division)."""
import numpy as np

from repro.data import (apply_imbalance, dirichlet_partition,
                        global_distribution, label_distributions,
                        sort_and_partition)


def make_labels(n_classes=10, per_class=100, seed=0):
    rng = np.random.default_rng(seed)
    labels = np.repeat(np.arange(n_classes), per_class)
    rng.shuffle(labels)
    return labels


def test_sort_and_partition_coverage():
    labels = make_labels()
    rng = np.random.default_rng(0)
    parts = sort_and_partition(labels, 20, 2, rng)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)


def test_heterogeneity_decreases_with_shards():
    labels = make_labels()
    rng = np.random.default_rng(0)

    def avg_l1(parts):
        p = label_distributions(labels, parts, 10)
        g = global_distribution(labels, parts, 10)
        return np.abs(p - g).sum(axis=1).mean()

    h1 = avg_l1(sort_and_partition(labels, 10, 1, rng))
    h5 = avg_l1(sort_and_partition(labels, 10, 5, rng))
    assert h1 > h5


def test_single_shard_single_class():
    """l=1 with V == C gives (nearly) single-class devices — the paper's
    FSCD-Gc regime."""
    labels = make_labels(10, 100)
    rng = np.random.default_rng(0)
    parts = sort_and_partition(labels, 10, 1, rng)
    p = label_distributions(labels, parts, 10)
    assert (p.max(axis=1) > 0.99).all()


def test_dirichlet_sizes_equal():
    labels = make_labels()
    rng = np.random.default_rng(0)
    parts = dirichlet_partition(labels, 16, 0.5, rng)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1
    assert sum(sizes) <= len(labels)


def test_dirichlet_alpha_controls_heterogeneity():
    labels = make_labels(10, 500)
    rng = np.random.default_rng(0)

    def avg_l1(alpha):
        parts = dirichlet_partition(labels, 16, alpha,
                                    np.random.default_rng(1))
        p = label_distributions(labels, parts, 10)
        g = np.bincount(labels, minlength=10) / len(labels)
        return np.abs(p - g).sum(axis=1).mean()

    assert avg_l1(0.1) > avg_l1(10.0)


def test_imbalance_ratio():
    labels = make_labels(10, 100)
    rng = np.random.default_rng(0)
    idx = apply_imbalance(labels, 3.0, rng)
    sub = labels[idx]
    n1 = (sub < 5).sum()
    n2 = (sub >= 5).sum()
    assert abs(n2 / n1 - 3.0) < 0.3


def test_label_distributions_rows_sum_to_one():
    labels = make_labels()
    rng = np.random.default_rng(0)
    parts = dirichlet_partition(labels, 8, 1.0, rng)
    p = label_distributions(labels, parts, 10)
    assert np.allclose(p.sum(axis=1), 1.0)
