"""Benchmark driver: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).

  fig3   -- scheduling-solver quality (rel. error + iterations)
  tab2   -- scheduled count + WEMD per algorithm
  fig4-5 -- FL accuracy, balanced + imbalanced total dataset
  fig8   -- scheduled count vs Dirichlet alpha (full V=64 + channel)
  fig9   -- G / sigma indicator dynamics
  eq9    -- Lambert-W bandwidth vs bisection oracle
  kernel -- Pallas kernels (interpret-mode correctness path)
  multicell -- multi-cell round engine throughput (rounds/sec vs C,
            fused vs pre-fusion round core; writes BENCH_multicell.json)
  roofline -- aggregates the dry-run artifacts (the Roofline table)

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig3,tab2]
"""
from __future__ import annotations

import argparse
import sys
import time


BENCHES = [
    ("fig3", "benchmarks.bench_scheduling"),
    ("eq9", "benchmarks.bench_bandwidth"),
    ("fig8", "benchmarks.bench_fl_dirichlet"),
    ("kernel", "benchmarks.bench_kernels"),
    ("multicell", "benchmarks.bench_multicell"),
    ("roofline", "benchmarks.bench_roofline"),
    ("tab2", "benchmarks.bench_wemd_table"),
    ("fig9", "benchmarks.bench_gsigma"),
    ("fig4-5", "benchmarks.bench_fl_accuracy"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated bench tags to run")
    args = ap.parse_args()
    only = set(t for t in args.only.split(",") if t)

    print("name,us_per_call,derived")
    failures = 0
    for tag, modname in BENCHES:
        if only and tag not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            for line in mod.run():
                print(line, flush=True)
            print(f"# {tag} done in {time.time() - t0:.0f}s", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"# {tag} FAILED: {type(e).__name__}: {e}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
