"""Table II: average scheduled-device count + average WEMD per scheduling
algorithm, on a common sequence of FL rounds (miniature world)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import mini_fl_world, row
from repro.fl import FederatedTrainer, FLConfig


ALGS = ["fedcgd-fscd", "fedcgd-gs", "fcbs", "poc", "bn", "bc", "random"]


def run() -> list:
    rows = []
    model, train, test, parts = mini_fl_world(partition="dirichlet",
                                              alpha=0.5, V=12)
    import time
    for alg in ALGS:
        fl = FLConfig(num_devices=12, available_prob=0.8, batch_size=8,
                      tau=1, scheduler=alg, eval_every=0, seed=1)
        tr = FederatedTrainer(model, train, test, parts, fl)
        t0 = time.perf_counter()
        hist = tr.run(8)
        us = (time.perf_counter() - t0) / 8 * 1e6
        sched = np.mean([h["num_scheduled"] for h in hist])
        # report label-EMD with unit weights for cross-alg comparability
        wemd = np.mean([h["wemd"] / max(h["g_hat"], 1e-9) for h in hist])
        rows.append(row(f"tab2/sched_num/{alg}", us, f"{sched:.2f}"))
        rows.append(row(f"tab2/wemd/{alg}", us, f"{wemd:.3f}"))
    return rows
