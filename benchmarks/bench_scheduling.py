"""Fig. 3: scheduling-solver quality — relative error + iteration counts
of GS and FSCD against the CD baseline (and the exact optimum for small
V) — plus a batch-size x V throughput sweep of the batched jax engine
(``solve_many``) against the per-problem numpy loop (solves/sec)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed
from repro.core import scheduling as S


def make_problem(rng, V, C=10):
    # calibrated to the paper's mid-training magnitudes: sigma-hat ~ 2-6
    # (Fig. 9), G-hat ~ O(1), b = 32
    p_dev = rng.dirichlet(np.ones(C) * 0.4, size=V)
    return S.Problem(
        p_dev=p_dev, global_dist=rng.dirichlet(np.ones(C) * 3.0),
        class_weights=rng.uniform(0.5, 1.5, C), sigma=rng.uniform(2.0, 6.0),
        batch_size=32, min_bw=rng.uniform(0.4, 1.6, V), total_bw=V * 0.5)


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    for V in (8, 16, 32, 64):
        trials = 12 if V <= 16 else 8
        errs = {"GS": [], "FSCD": []}
        iters = {"GS": [], "FSCD": [], "CD": []}
        uss = {"GS": [], "FSCD": [], "CD": []}
        for _ in range(trials):
            prob = make_problem(rng, V)
            cd, us_cd = timed(S.coordinate_descent, prob, repeats=1)
            baseline = cd.objective
            if V <= 16:
                baseline = min(baseline, S.exhaustive(prob).objective)
            gs, us_gs = timed(S.greedy_scheduling, prob, repeats=1)
            fs, us_fs = timed(S.fscd, prob, repeats=1)
            errs["GS"].append(gs.objective / baseline - 1)
            errs["FSCD"].append(fs.objective / baseline - 1)
            iters["GS"].append(gs.iterations)
            iters["FSCD"].append(fs.iterations)
            iters["CD"].append(cd.iterations)
            uss["GS"].append(us_gs)
            uss["FSCD"].append(us_fs)
            uss["CD"].append(us_cd)
        for alg in ("GS", "FSCD"):
            rows.append(row(
                f"fig3/rel_err/{alg}/V{V}", np.mean(uss[alg]),
                f"{np.mean(errs[alg]) * 100:.2f}%"))
        for alg in ("GS", "FSCD", "CD"):
            rows.append(row(
                f"fig3/iterations/{alg}/V{V}", np.mean(uss[alg]),
                f"{np.mean(iters[alg]):.1f}"))
    rows += run_batched()
    return rows


def run_batched() -> list:
    """Batched-engine throughput: ``solve_many`` (jax) vs the numpy
    loop, swept over batch size x V.  ``timed`` warms up once, so jit
    compilation is excluded from the reported numbers."""
    rows = []
    numpy_fn = {"gs": S.greedy_scheduling, "fscd": S.fscd}
    for V in (16, 64):
        for B in (8, 32, 64):
            rng = np.random.default_rng(100 + V + B)
            probs = [make_problem(rng, V) for _ in range(B)]
            for alg in ("gs", "fscd"):
                _, us_np = timed(
                    lambda: [numpy_fn[alg](p) for p in probs], repeats=3)
                _, us_jx = timed(S.solve_many, probs, alg, repeats=3)
                sps_np = B / (us_np * 1e-6)
                sps_jx = B / (us_jx * 1e-6)
                rows.append(row(f"batched/{alg}/numpy/V{V}/B{B}", us_np,
                                f"{sps_np:.1f} solves/s"))
                rows.append(row(
                    f"batched/{alg}/jax/V{V}/B{B}", us_jx,
                    f"{sps_jx:.1f} solves/s ({us_np / us_jx:.2f}x numpy)"))
    return rows
