"""Multi-cell round-engine throughput (the perf trajectory artifact).

Two metrics per cell count C, on a micro CNN world (8x8 images,
quarter-width paper CNN — small enough that engine overhead, not conv
FLOPs, is what's measured):

  * ``fresh``  — wall-clock per aggregation step of a *from-scratch
    experiment*: construct the trainer(s), run R rounds.  This is what
    "simulate C cells" costs in practice: C sequential
    ``FederatedTrainer``s compile C identical round cores + finalize
    helpers and issue C scheduling dispatches per round, while
    ``MultiCellTrainer`` compiles one rolled core and schedules all
    cells in one ``solve_many`` batch.  Process-global JAX warmup and
    the module-level jit caches are paid before either arm.
  * ``steady`` — wall-clock per aggregation step once everything is
    compiled (the long-run marginal round cost).

Also measured: ``fused_core`` vs ``legacy_core`` — the single-cell
round hot path (local update + Eq. 10 sigmas + deltas + norms + host
pull) as one fused program vs the pre-fusion per-device dispatch loop.

Every number lands in ``BENCH_multicell.json`` (machine-readable; path
override via ``BENCH_MULTICELL_JSON``) next to the CSV rows.
``BENCH_MULTICELL_DRY=1`` shrinks the sweep to a CI-smoke size.
``available_prob`` is pinned to 1.0 so every round reuses one compiled
shape.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import row


def _world(V=8, seed=0):
    from repro.configs.paper_cnn import CNNConfig
    from repro.data import (sort_and_partition, synthetic_image_dataset,
                            train_test_split)
    from repro.models import build_model

    ds = synthetic_image_dataset(num_classes=2, num_per_class=40,
                                 image_size=8, seed=seed)
    train, test = train_test_split(ds, seed=seed)
    parts = sort_and_partition(train.labels, V, 1,
                               np.random.default_rng(seed))
    model = build_model(CNNConfig(name="micro-cnn", kind="paper_cnn",
                                  num_classes=2, image_size=8,
                                  dropout=False, width=0.25))
    return model, train, test, parts


def _fl_cfg(V, cells=1, seed=0):
    from repro.fl import FLConfig
    return FLConfig(num_devices=V, available_prob=1.0, batch_size=2,
                    tau=1, scheduler="fedcgd-fscd",
                    scheduler_backend="jax", eval_every=0, seed=seed,
                    num_cells=cells)


def _legacy_core(tr, prep, sig1):
    """The pre-fusion round hot path: one jit dispatch + host pull per
    device for sigma, one ``float()`` sync per device for the delta
    norms (what ``run_round`` did before the fused core)."""
    import jax
    from repro.core.estimation import tree_norm

    dev_params, dev_losses = tr._local_update(tr.params, prep.batches,
                                              prep.subkey)
    dev_losses = np.asarray(dev_losses)
    first = jax.tree.map(lambda x: x[:, 0], prep.batches)
    sigma_v = np.array([
        float(sig1(tr.params, jax.tree.map(lambda x, i=i: x[i], first)))
        for i in range(len(prep.avail_idx))])
    deltas = jax.tree.map(lambda new, old: new - old[None],
                          dev_params, tr.params)
    delta_norms = np.array([
        float(tree_norm(jax.tree.map(lambda x, i=i: x[i], deltas)))
        for i in range(len(prep.avail_idx))])
    return dev_losses, sigma_v, delta_norms


def _fused_core(tr, prep):
    import jax
    import jax.numpy as jnp

    out = tr._round_core(jax.tree.map(lambda x: x[None], tr.params),
                         jax.tree.map(lambda x: x[None], prep.batches),
                         jnp.stack([prep.subkey]))
    lh, sh, nh = jax.device_get((out[1], out[2], out[4]))
    return lh[0], sh[0], nh[0]


def run():
    from repro.fl import FederatedTrainer, MultiCellTrainer

    dry = os.environ.get("BENCH_MULTICELL_DRY", "") not in ("", "0")
    V = 8
    rounds = 2 if dry else 4
    steady_rounds = 3 if dry else 8
    cells_sweep = [1, 4] if dry else [1, 2, 4, 8]
    results = {"dry": dry, "V": V, "rounds": rounds,
               "fresh_multicell_us": {}, "fresh_sequential_us": {},
               "fresh_speedup": {},
               "steady_multicell_us": {}, "steady_sequential_us": {},
               "steady_speedup": {}, "rounds_per_sec": {},
               "host_syncs": {}}

    model, train, test, parts = _world(V)
    # global warmup: JAX backend init + the module-level jit caches that
    # both arms share (single-cell shapes), outside every timer
    warm = FederatedTrainer(model, train, test, parts, _fl_cfg(V, seed=99))
    for j in range(2):
        warm.run_round(j)

    for C in cells_sweep:
        t0 = time.perf_counter()
        mc = MultiCellTrainer(model, train, test, parts,
                              _fl_cfg(V, cells=C))
        for j in range(rounds):
            mc.run_round(j)
        us_mc = (time.perf_counter() - t0) / rounds * 1e6

        t0 = time.perf_counter()
        seq = [FederatedTrainer(model, train, test, parts,
                                _fl_cfg(V, seed=c)) for c in range(C)]
        for j in range(rounds):
            for tr in seq:
                tr.run_round(j)
        us_seq = (time.perf_counter() - t0) / rounds * 1e6

        t0 = time.perf_counter()
        for j in range(rounds, rounds + steady_rounds):
            mc.run_round(j)
        st_mc = (time.perf_counter() - t0) / steady_rounds * 1e6
        t0 = time.perf_counter()
        for j in range(rounds, rounds + steady_rounds):
            for tr in seq:
                tr.run_round(j)
        st_seq = (time.perf_counter() - t0) / steady_rounds * 1e6

        results["fresh_multicell_us"][str(C)] = us_mc
        results["fresh_sequential_us"][str(C)] = us_seq
        results["fresh_speedup"][str(C)] = us_seq / us_mc
        results["steady_multicell_us"][str(C)] = st_mc
        results["steady_sequential_us"][str(C)] = st_seq
        results["steady_speedup"][str(C)] = st_seq / st_mc
        results["rounds_per_sec"][str(C)] = 1e6 / st_mc
        # device->host syncs of the last full C-cell round — the batched
        # phase engine's contract is a constant count independent of C
        results["host_syncs"][str(C)] = int(mc.last_round_host_syncs)
        yield row(f"multicell_fresh_C{C}", us_mc,
                  f"speedup={us_seq / us_mc:.2f}x")
        yield row(f"multicell_steady_C{C}", st_mc,
                  f"speedup={st_seq / st_mc:.2f}x "
                  f"host_syncs={mc.last_round_host_syncs}")

    # single-cell hot path: fused core vs the pre-fusion device loop
    import jax
    tr = FederatedTrainer(model, train, test, parts, _fl_cfg(V))
    sig1 = jax.jit(tr._sigma_one)
    prep = tr._prepare_round(0)
    for fn, args in ((_fused_core, (tr, prep)),
                     (_legacy_core, (tr, prep, sig1))):
        fn(*args)                                  # warmup / compile
    reps = 3 if dry else 10
    t0 = time.perf_counter()
    for _ in range(reps):
        _fused_core(tr, prep)
    us_fused = (time.perf_counter() - t0) / reps * 1e6
    t0 = time.perf_counter()
    for _ in range(reps):
        _legacy_core(tr, prep, sig1)
    us_legacy = (time.perf_counter() - t0) / reps * 1e6
    results["fused_core_us"] = us_fused
    results["legacy_core_us"] = us_legacy
    results["fusion_speedup"] = us_legacy / us_fused
    yield row("fused_core", us_fused, f"V={V}")
    yield row("legacy_core", us_legacy,
              f"fusion_speedup={us_legacy / us_fused:.2f}x")

    # per-phase breakdown through repro.obs: warmup rounds pay (and
    # count) the compiles, the registry then resets in place so the
    # span histograms hold steady-state rounds only
    import dataclasses
    from repro.obs import ObsConfig
    C_obs = cells_sweep[-1]
    mco = MultiCellTrainer(
        model, train, test, parts,
        dataclasses.replace(_fl_cfg(V, cells=C_obs),
                            obs=ObsConfig(enabled=True)))
    for j in range(rounds):
        mco.run_round(j)
    m = mco.obs.metrics
    results["compile"] = {
        "count": int(m.counter("xla.compiles_total").value),
        "seconds": m.counter("xla.compile_seconds_total").value,
    }
    m.reset()
    for j in range(rounds, rounds + steady_rounds):
        mco.run_round(j)
    results["phase_us"] = {
        name[len("span."):]: {
            "count": h.count,
            "mean_us": h.mean * 1e6,
            "p50_us": h.percentile(0.5) * 1e6,
            "p95_us": h.percentile(0.95) * 1e6,
        }
        for name, h in sorted(m.histograms.items())
        if name.startswith("span.") and h.count}
    results["phase_cells"] = C_obs
    for name, p in results["phase_us"].items():
        yield row(f"phase_{name}_C{C_obs}", p["mean_us"],
                  f"p95={p['p95_us']:.0f}us")
    yield row("compile_seconds", results["compile"]["seconds"] * 1e6,
              f"compiles={results['compile']['count']}")

    path = os.environ.get("BENCH_MULTICELL_JSON", "BENCH_multicell.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    yield row("json_artifact", 0.0, path)
