"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape x
mesh) roofline table (markdown written to experiments/roofline_table.md)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")
OPT_DIR = os.path.join(os.path.dirname(__file__), "..",
                       "experiments", "dryrun_opt")
OUT_MD = os.path.join(os.path.dirname(__file__), "..",
                      "experiments", "roofline_table.md")


def load_all(d=DRYRUN_DIR):
    out = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def _next_lever(d) -> str:
    """One sentence on what would move the dominant term down (brief
    §Roofline requirement)."""
    r = d["roofline"]
    bn = r["bottleneck"]
    kind = d["kind"]
    moe = "moe" in d["arch"] or d["arch"].startswith(("arctic", "moonshot"))
    if bn == "compute":
        return "compute-bound: already near useful-FLOPs roofline; raise " \
               "per-chip batch or accept"
    if bn == "memory":
        if kind == "decode":
            return "int8/fp8 KV cache halves the dominant cache reads"
        if moe:
            return "fused Pallas MoE dispatch (megablox-style) removes the " \
                   "gather/scatter round-trips; TPU bf16 lowering removes " \
                   "the CPU-backend f32 emulation share"
        return "Pallas flash attention (in kernels/) keeps scores in VMEM " \
               "on TPU; TPU bf16 lowering removes the CPU f32 share"
    if kind == "decode":
        return "batch more requests per step to amortize the weight " \
               "gathers/psums across tokens"
    if d["tp_mode"]:
        return "overlap TP psums/gathers with compute " \
               "(latency-hiding collective scheduling)"
    return "fewer FSDP weight gathers: larger per-device batch per gather " \
           "or gather-once remat policy"


def make_table(results):
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s |"
        " bottleneck | peak GiB | MODEL/HLO flops | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in results:
        r = d["roofline"]
        pk = d["memory_analysis"]["peak_bytes_per_device"] / 2 ** 30
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['bottleneck']} "
            f"| {pk:.1f} | {r['flops_ratio']:.2f} | {_next_lever(d)} |")
    return "\n".join(lines)


def run() -> list:
    results = load_all()
    if not results:
        return [row("roofline/table", 0.0, "NO_DRYRUN_ARTIFACTS")]
    opt = load_all(OPT_DIR)
    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    with open(OUT_MD, "w") as f:
        f.write("# Roofline tables (from dry-run artifacts)\n\n")
        f.write("## Baseline (paper-faithful naive sharding)\n\n")
        f.write(make_table(results) + "\n")
        if opt:
            f.write("\n## Optimized (beyond-paper §Perf sharding)\n\n")
            f.write(make_table(opt) + "\n")
    rows = [row("roofline/pairs", 0.0, len(results))]
    by_bn = {}
    for d in results:
        by_bn.setdefault(d["roofline"]["bottleneck"], []).append(d)
    for bn, ds in sorted(by_bn.items()):
        rows.append(row(f"roofline/bottleneck/{bn}", 0.0, len(ds)))
    fits = sum(1 for d in results
               if d["memory_analysis"]["peak_bytes_per_device"] < 16 * 2**30)
    rows.append(row("roofline/fits_16GiB", 0.0, f"{fits}/{len(results)}"))
    if opt:
        rows.append(row("roofline/opt_pairs", 0.0, len(opt)))
        # geometric-mean improvement of the dominant terms
        import math
        gains = []
        base_by_key = {(d["arch"], d["shape"], d["mesh"]): d for d in results}
        for d in opt:
            b = base_by_key.get((d["arch"], d["shape"], d["mesh"]))
            if not b:
                continue
            tb = max(b["roofline"]["compute_s"], b["roofline"]["memory_s"],
                     b["roofline"]["collective_s"])
            to = max(d["roofline"]["compute_s"], d["roofline"]["memory_s"],
                     d["roofline"]["collective_s"])
            if tb > 0 and to > 0:
                gains.append(tb / to)
        if gains:
            g = math.exp(sum(math.log(x) for x in gains) / len(gains))
            rows.append(row("roofline/opt_dominant_term_geomean_speedup",
                            0.0, f"{g:.2f}x"))
    return rows
