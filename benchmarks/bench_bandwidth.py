"""Eq. 9: Lambert-W minimal bandwidth — accuracy vs the bisection oracle
and per-call latency (it runs once per device per round in Algorithm 3)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, timed
from repro.core.bandwidth import (min_bandwidth, min_bandwidth_bisect)


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    sh = 10 ** rng.uniform(-13, -8, size=64)
    N0 = 10 ** ((-174 + 6) / 10) * 1e-3
    bits, dl = 1e7, 2.0

    _, us_vec = timed(min_bandwidth, bits, dl, sh, N0, repeats=20)
    bw = min_bandwidth(bits, dl, sh, N0)
    errs = []
    t0 = time.perf_counter()
    for s, b in zip(sh, bw):
        ref = min_bandwidth_bisect(bits, dl, s, N0)
        if ref > 0:
            errs.append(abs(b - ref) / ref)
    us_bisect = (time.perf_counter() - t0) / len(sh) * 1e6
    rows.append(row("eq9/lambertw_64dev", us_vec, f"max_err={max(errs):.2e}"))
    rows.append(row("eq9/bisect_per_dev", us_bisect, "oracle"))
    rows.append(row("eq9/feasible_frac", us_vec,
                    f"{(bw > 0).mean():.2f}"))
    return rows
