"""Fig. 7/8: behaviour across Dirichlet alpha — scheduled-device count
(FedCGD schedules more devices as data homogenizes) and epochs-to-target.
The scheduled-count figure (Fig. 8) needs no training, so it runs at the
paper's full V=64 with the real channel."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.core import scheduling as S


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    V, C = 64, 10
    for alpha in (0.1, 1.0, 10.0):
        counts, wemds, uss = [], [], []
        for _ in range(10):
            p_dev = rng.dirichlet(np.ones(C) * alpha, size=V)
            avail = rng.random(V) < 0.3            # paper p_a = 0.3
            idx = np.flatnonzero(avail)
            prob = S.Problem(
                p_dev=p_dev[idx], global_dist=np.ones(C) / C,
                class_weights=np.ones(C), sigma=1.0, batch_size=32,
                min_bw=rng.uniform(0.5e6, 3e6, len(idx)), total_bw=20e6)
            t0 = time.perf_counter()
            sched = S.fscd(prob)
            uss.append((time.perf_counter() - t0) * 1e6)
            counts.append(sched.num_scheduled)
            wemds.append(sched.wemd)
        rows.append(row(f"fig8/sched_num/alpha{alpha}", np.mean(uss),
                        f"{np.mean(counts):.1f}"))
        rows.append(row(f"fig8/wemd/alpha{alpha}", np.mean(uss),
                        f"{np.mean(wemds):.3f}"))
    return rows
