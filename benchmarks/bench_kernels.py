"""Pallas-kernel microbench: interpret-mode correctness-path timings
(CPU container; wall-times are NOT TPU perf — the roofline table in
EXPERIMENTS.md carries the perf story) + allclose deltas vs oracles."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.persample_gradnorm import persample_gradnorm_pallas
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.rwkv_scan import wkv_pallas


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)

    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    out, us = timed(lambda: jax.block_until_ready(
        flash_attention(q, q, q, causal=True, interpret=True)), repeats=2)
    expect = ref.attention_ref(q, q, q, causal=True)
    rows.append(row("kernel/flash_attn/256x64", us,
                    f"maxerr={float(jnp.abs(out - expect).max()):.1e}"))

    B, T, H, hd = 1, 128, 2, 64
    r = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = r * 0.3
    v = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    w = jnp.asarray(jax.nn.sigmoid(rng.normal(size=(B, T, H, hd))) * 0.5
                    + 0.45, jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, hd)) * 0.1, jnp.float32)
    (y, s), us = timed(lambda: jax.block_until_ready(
        wkv_pallas(r, k, v, w, u, interpret=True)), repeats=2)
    yr, _ = ref.wkv_ref(r, k, v, w, u)
    rows.append(row("kernel/wkv/128x2x64", us,
                    f"maxerr={float(jnp.abs(y - yr).max()):.1e}"))

    a = jnp.asarray(rng.uniform(0.9, 0.999, (2, 256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(2, 256, 256)), jnp.float32)
    h0 = jnp.zeros((2, 256), jnp.float32)
    (y, hT), us = timed(lambda: jax.block_until_ready(
        rglru_pallas(a, b, h0, interpret=True)), repeats=2)
    yr, _ = ref.rglru_ref(a, b, h0)
    rows.append(row("kernel/rglru/256x256", us,
                    f"maxerr={float(jnp.abs(y - yr).max()):.1e}"))

    h = jnp.asarray(rng.normal(size=(128, 120)), jnp.float32)
    lg = jnp.asarray(rng.normal(size=(128, 10)), jnp.float32)
    yl = jnp.asarray(rng.integers(0, 10, 128), jnp.int32)
    (sig, _), us = timed(lambda: jax.block_until_ready(
        persample_gradnorm_pallas(h, lg, yl, interpret=True)), repeats=2)
    sr, _ = ref.persample_gradnorm_ref(h, lg, yl)
    rows.append(row("kernel/psg/128x120x10", us,
                    f"err={abs(float(sig - sr)):.1e}"))
    return rows
