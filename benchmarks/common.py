"""Shared benchmark scaffolding. Every benchmark prints CSV rows
``name,us_per_call,derived`` (derived = the paper-figure quantity)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, us_per_call)."""
    fn(*args, **kw)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"


def mini_fl_world(num_classes=4, per_class=100, image_size=16, noise=0.5,
                  seed=0, V=12, partition="sort", l=1, alpha=0.5, r=1.0):
    """A small synthetic FL world shared by the Fig.4/5/7/8/9 analogues."""
    import dataclasses as dc
    from repro.configs.paper_cnn import PAPER_CNN_CIFAR10
    from repro.data import (apply_imbalance, dirichlet_partition,
                            sort_and_partition, synthetic_image_dataset,
                            train_test_split)
    from repro.models import build_model

    ds = synthetic_image_dataset(num_classes=num_classes,
                                 num_per_class=per_class,
                                 image_size=image_size, noise=noise,
                                 seed=seed)
    train, test = train_test_split(ds, seed=seed)
    rng = np.random.default_rng(seed)
    labels = train.labels
    if r != 1.0:
        idx = apply_imbalance(labels, r, rng)
        train = dc.replace(train, inputs=train.inputs[idx],
                           labels=labels[idx]) if dc.is_dataclass(train) else train
        labels = train.labels
    if partition == "sort":
        parts = sort_and_partition(labels, V, l, rng)
    else:
        parts = dirichlet_partition(labels, V, alpha, rng)
    cfg = dc.replace(PAPER_CNN_CIFAR10.reduced(), num_classes=num_classes)
    model = build_model(cfg)
    return model, train, test, parts
