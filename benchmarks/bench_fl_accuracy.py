"""Fig. 4 + Fig. 5: test accuracy of FedCGD vs baselines on balanced
(r=1) and imbalanced (r=3, 9) total datasets (miniature analogue: 4-class
synthetic images, 12 devices, reduced CNN)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import mini_fl_world, row
from repro.fl import FederatedTrainer, FLConfig

ALGS = ["fedcgd-fscd", "fedcgd-gs", "bc", "random"]
ROUNDS = 15


def run() -> list:
    rows = []
    for r in (1.0, 3.0):
        for alg in ALGS:
            model, train, test, parts = mini_fl_world(
                partition="sort", l=1, V=12, r=r, seed=2)
            fl = FLConfig(num_devices=12, available_prob=0.8, batch_size=8,
                          tau=1, scheduler=alg, eval_every=0, seed=2)
            tr = FederatedTrainer(model, train, test, parts, fl)
            t0 = time.perf_counter()
            tr.run(ROUNDS)
            us = (time.perf_counter() - t0) / ROUNDS * 1e6
            acc = tr.evaluate()
            rows.append(row(f"fig4-5/acc/r{int(r)}/{alg}", us,
                            f"{acc:.3f}"))
    return rows
