"""Fig. 9: evolution of the estimated G and sigma during training —
G/sigma is the paper's indicator of when device-level CGD matters."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import mini_fl_world, row
from repro.fl import FederatedTrainer, FLConfig


def run() -> list:
    rows = []
    for tau in (1, 3):
        model, train, test, parts = mini_fl_world(partition="sort", l=2,
                                                  V=12, seed=4)
        fl = FLConfig(num_devices=12, available_prob=0.8, batch_size=8,
                      tau=tau, scheduler="fedcgd-fscd", eval_every=0, seed=4)
        tr = FederatedTrainer(model, train, test, parts, fl)
        t0 = time.perf_counter()
        hist = tr.run(10)
        us = (time.perf_counter() - t0) / 10 * 1e6
        g0, g1 = hist[0]["g_hat"], hist[-1]["g_hat"]
        s0, s1 = hist[0]["sigma_hat"], hist[-1]["sigma_hat"]
        rows.append(row(f"fig9/G/tau{tau}", us, f"{g0:.3f}->{g1:.3f}"))
        rows.append(row(f"fig9/sigma/tau{tau}", us, f"{s0:.3f}->{s1:.3f}"))
        rows.append(row(f"fig9/G_over_sigma/tau{tau}", us,
                        f"{g1 / max(s1, 1e-9):.3f}"))
    return rows
